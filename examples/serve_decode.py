"""Serving example: batched prefill + autoregressive decode on a mesh,
using the sharded serve_step (KV cache: batch × data, sequence × model).

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/serve_decode.py
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Server
from repro.models import api
from repro.models.cache import pad_cache
from repro.models.config import InputShape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.reduced_config(args.arch)
    cfg = dataclasses.replace(cfg, n_layers=4)
    mesh = make_host_mesh(model=1)
    total = args.prompt_len + args.new_tokens
    shape = InputShape("serve", seq_len=total, global_batch=args.batch, kind="decode")

    params = api.model_init(cfg, jax.random.PRNGKey(0))
    prompt = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.arch_type == "vlm":
        prompt["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.vlm.n_patches, cfg.d_model)
        )
    if cfg.arch_type == "encdec":
        prompt["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encdec.n_enc_frames, cfg.d_model)
        )

    logits, cache = api.model_prefill(params, cfg, prompt, jnp.float32)
    cache = pad_cache(cache, total)
    first = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    server = Server(cfg, shape, mesh, dtype=jnp.float32)
    p_sh = server.load_params(params)
    toks, _ = server.decode(
        p_sh, first, cache, start_t=args.prompt_len, n_tokens=args.new_tokens
    )
    print(f"arch={args.arch}  decoded {toks.shape} tokens")
    print("sample:", toks[0].tolist())


if __name__ == "__main__":
    main()
