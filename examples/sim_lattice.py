"""Scenario-lattice quickstart: a whole paper-style sweep in one program.

Runs (3 policies × 2 noise powers × 4 trials) = 24 cells of PO-FL training
through ``repro.sim`` — ONE policy-fused vmapped+scanned compile for the
whole sweep (the policy axis is traced), metrics streamed out once — under
temporally-correlated Gauss–Markov fading with random device dropout
(scenarios the per-round ``run_pofl`` loop cannot express). Set
``REPRO_COMPILE_CACHE=<dir>`` to persist that one compile across runs. ``--mesh N`` shards the 8-cell-per-policy axis over N devices
(results are identical — only placement changes):

    PYTHONPATH=src python examples/sim_lattice.py [--backend pallas_fused]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sim_lattice.py --mesh 8

``--algorithms a,b`` (``repro.core.local_update.ALGORITHMS`` names) adds a
traced local-update algorithm axis — still the same single compile — and
``--local-steps K`` runs K local SGD steps per device per round:

    PYTHONPATH=src python examples/sim_lattice.py \
        --algorithms fedavg,fedprox --local-steps 3

``--distributed`` initializes ``jax.distributed`` from the ``REPRO_DIST_*``
env contract and shards the cell axis over the GLOBAL (process-spanning)
device list — run it under the local launcher (2 hosts × 4 fake CPU devices
each; every host prints the same gathered records):

    PYTHONPATH=src python -m repro.launch.distributed \
        --procs 2 --devices-per-proc 4 -- \
        python examples/sim_lattice.py --distributed
"""
import argparse

import jax
import numpy as np

from repro.core.pofl import BACKENDS, POFLConfig
from repro.data.synthetic import make_classification_dataset
from repro.models import small
from repro.sim import (
    LatticeSpec,
    enable_compile_cache,
    initialize_distributed,
    lattice_compile_stats,
    make_cell_mesh,
    make_global_cell_mesh,
    make_partition,
    run_lattice,
)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend", default="jnp", choices=BACKENDS,
        help="aggregation backend (pallas_fused = fused kernel on TPU, "
        "its jnp oracle on CPU)",
    )
    parser.add_argument(
        "--mesh", type=int, default=0, metavar="N",
        help="shard the cell axis over the first N local devices "
        "(0 = unsharded; on CPU set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N first)",
    )
    parser.add_argument(
        "--distributed", action="store_true",
        help="initialize jax.distributed from the REPRO_DIST_* env contract "
        "and shard the cell axis over ALL global devices (see "
        "repro.launch.distributed)",
    )
    parser.add_argument(
        "--rounds", type=int, default=30, metavar="T",
        help="rounds per cell (shrink for smoke runs)",
    )
    parser.add_argument(
        "--algorithms", type=str, default="fedavg", metavar="A[,B...]",
        help="comma-separated local-update algorithms "
        "(repro.core.local_update.ALGORITHMS names); >1 name sweeps the "
        "traced algorithm axis inside the same single compile",
    )
    parser.add_argument(
        "--local-steps", type=int, default=1, metavar="K",
        help="local SGD steps per device per round (1 = the classic "
        "single-gradient round)",
    )
    args = parser.parse_args(argv)
    algorithms = tuple(s.strip() for s in args.algorithms.split(","))

    # REPRO_COMPILE_CACHE=<dir> persists the lattice's XLA compile across
    # runs (repro.sim.compile_cache); no-op when unset
    cache_dir = enable_compile_cache()

    if args.distributed:
        # must precede the first device query; a missing env contract just
        # degrades to a single-process run over the local devices
        initialize_distributed()
        mesh = make_global_cell_mesh(args.mesh or None)  # --mesh counts GLOBAL devices here
    else:
        mesh = make_cell_mesh(args.mesh) if args.mesh else None

    key = jax.random.PRNGKey(0)
    k_train, k_test, k_init = jax.random.split(key, 3)
    x_tr, y_tr = make_classification_dataset("mnist_like", 3000, k_train)
    x_te, y_te = make_classification_dataset("mnist_like", 1000, k_test)
    # Dirichlet(0.3) label skew — the sim subsystem's third partition preset
    data = make_partition("dirichlet", x_tr, y_tr, n_devices=20, beta=0.3)

    params0 = small.init_logreg(k_init)
    eval_fn = small.make_eval_fn(small.logreg_logits, small.logreg_loss, x_te, y_te)

    spec = LatticeSpec(
        policies=("pofl", "importance", "channel"),
        noise_powers=(1e-11, 1e-9),
        seeds=(0, 1000, 2000, 3000),
        n_rounds=args.rounds,
        eval_every=10,
        algorithms=algorithms,
    )
    records = run_lattice(
        small.logreg_loss, data, params0, spec,
        base_cfg=POFLConfig(n_devices=20, n_scheduled=8, backend=args.backend,
                            local_steps=args.local_steps),
        eval_fn=eval_fn,
        scenario="dropout",
        scenario_params={"base": "gauss_markov", "corr": 0.9, "p_drop": 0.1},
        mesh=mesh,
    )

    if mesh is None:
        shard_note = ""
    else:
        n_dev = int(np.asarray(mesh.devices).size)
        shard_note = f", cells sharded over {n_dev} devices"
        if args.distributed:
            shard_note += f" ({jax.process_count()} hosts)"
    cs = lattice_compile_stats()
    cache_note = f", compile cache {cache_dir}" if cache_dir else ""
    print(f"lattice: {spec.n_cells} cells × {spec.n_rounds} rounds "
          f"(eval rounds {records.eval_rounds.tolist()}){shard_note} — "
          f"{cs['n_compiles']} compile(s), {cs['compile_seconds']:.1f}s"
          f"{cache_note}")
    for policy in spec.policies:
        for np_ in spec.noise_powers:
            acc = records.cell(policy=policy, noise_power=np_)["acc"]
            best = np.mean(np.max(acc, axis=-1))  # mean-over-trials best acc
            print(f"  {policy:>11s} @ σ_z²={np_:.0e}:  best_acc={best:.3f}")


if __name__ == "__main__":
    main()
