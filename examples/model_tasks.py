"""Real-model federated tasks on the lattice — and the golden recipe.

Runs the model-task battery configuration from ``tests/test_model_tasks.py``
verbatim and prints the full-precision accuracy/loss/n_correct curves: this
script IS the regeneration recipe for the ``GOLDEN_LOGREG`` / ``GOLDEN_CNN``
tables (rerun after an INTENTIONAL semantics change, paste the output).

The task factory (``repro.sim.make_model_task``) bundles a real pytree model
(784-dim logistic regression, or the 4-conv CNN with D = 258 634 raveled
params), Dirichlet-sized PADDED heterogeneous shards, and a pad-masked
:class:`~repro.sim.tasks.TaskEval` whose structured ``EvalRecord`` curves the
lattice stacks onto ``LatticeRecords.eval`` — the whole multi-policy sweep is
still ONE trace / ONE compile:

    PYTHONPATH=src python examples/model_tasks.py              # logreg (~10 s)
    PYTHONPATH=src python examples/model_tasks.py --task cnn   # CNN (~1-2 min)

CNN note: XLA CPU lowers in-scan conv grads to naive loops (~0.5 s per train
sample per round on one core), so the CNN cells are deliberately tiny — the
point is the paper-scale pytree plumbing, not throughput.
"""
import argparse
import time

import numpy as np

from repro.core.pofl import POFLConfig
from repro.sim import (
    FUSED_POLICY,
    LatticeSpec,
    cached_engine,
    make_model_task,
    run_lattice,
)

# the EXACT battery configurations tests/test_model_tasks.py pins
BATTERY = {
    "logreg": dict(
        task_kw=dict(kind="logreg", n_devices=8, partition="dirichlet_sized",
                     n_train=640, n_test=256, seed=0),
        cfg=dict(n_devices=8, n_scheduled=3, batch_size=8, lr0=0.1),
        spec=dict(n_rounds=6, eval_every=2),
    ),
    "cnn": dict(
        task_kw=dict(kind="cnn", n_devices=4, partition="dirichlet_sized",
                     n_train=64, n_test=24, seed=0, channel_bias=1.0),
        cfg=dict(n_devices=4, n_scheduled=2, batch_size=4, lr0=0.1),
        spec=dict(n_rounds=3, eval_every=2),
    ),
}


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--task", default="logreg", choices=sorted(BATTERY),
        help="which battery to run (and which golden table to print)",
    )
    args = parser.parse_args(argv)
    b = BATTERY[args.task]

    task = make_model_task(**b["task_kw"])
    spec = LatticeSpec(policies=("pofl", "channel"), noise_powers=(1e-11,),
                       alphas=(0.1,), seeds=(0,), **b["spec"])
    t0 = time.time()
    recs = run_lattice(
        task.loss_fn, task.data, task.params0, spec,
        base_cfg=POFLConfig(**b["cfg"]), eval_fn=task.eval,
    )
    dt = time.time() - t0
    eng = cached_engine(
        task.loss_fn, task.data,
        POFLConfig(policy=FUSED_POLICY, **b["cfg"]), eval_fn=task.eval,
    )
    print(f"{args.task}: D={task.dim} shards={np.asarray(task.data.n_samples)}"
          f" — {spec.n_cells} cells × {spec.n_rounds} rounds in {dt:.1f}s,"
          f" traces={eng.n_lattice_traces} compiles={eng.n_compiles}")
    print(f"eval rounds: {recs.eval_rounds.tolist()}")
    print(f'GOLDEN_{args.task.upper()} = {{')
    for pi, pol in enumerate(spec.policies):
        print(f'    "{pol}": {{')
        for f in ("acc", "loss", "n_correct"):
            curve = np.asarray(getattr(recs.eval, f)[0, pi, 0, 0, 0])
            print(f'        "{f}": {[float(v) for v in curve]},')
        print("    },")
    print("}")


if __name__ == "__main__":
    main()
