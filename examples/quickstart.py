"""Quickstart: the PO-FL framework in ~60 lines.

Trains a logistic-regression model over 30 simulated wireless devices with
over-the-air (AirComp) gradient aggregation, comparing the paper's channel
and gradient-importance aware scheduling against a channel-aware baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.channel import ChannelConfig
from repro.core.pofl import POFLConfig, run_pofl
from repro.data.partition import partition_noniid_shards
from repro.data.synthetic import make_classification_dataset
from repro.models import small


def main():
    # 1. data: synthetic MNIST-like, non-IID 2-classes-per-device shards
    key = jax.random.PRNGKey(0)
    k_train, k_test, k_init = jax.random.split(key, 3)
    x_tr, y_tr = make_classification_dataset("mnist_like", 3000, k_train)
    x_te, y_te = make_classification_dataset("mnist_like", 1000, k_test)
    data = partition_noniid_shards(x_tr, y_tr, n_devices=30)

    # 2. model: logistic regression (the paper's convex case)
    params0 = small.init_logreg(k_init)
    eval_fn = small.make_eval_fn(small.logreg_logits, small.logreg_loss, x_te, y_te)

    # 3. train under two scheduling policies
    for policy in ("pofl", "channel"):
        cfg = POFLConfig(policy=policy, n_scheduled=10, noise_power=1e-10)
        _, hist = run_pofl(
            small.logreg_loss, params0, data, cfg, n_rounds=30,
            eval_fn=eval_fn, eval_every=5,
            channel_cfg=ChannelConfig(n_devices=30, noise_power=1e-10),
        )
        print(f"policy={policy:>8s}  acc: "
              + " ".join(f"{a:.3f}" for a in hist.test_acc))


if __name__ == "__main__":
    main()
