"""End-to-end driver: PO-FL training of a ~100M-parameter language model on
a (CPU-host) mesh for a few hundred rounds — the distributed trainer stack
(launch/train.py) exercised for real, not just dry-run.

Default is a quick CPU-sized run; --rounds 200 --dmodel 768 --layers 12
reaches the ~100M-parameter scale of the deliverable (slow on CPU).

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/train_pofl_lm.py --rounds 30
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import make_token_dataset
from repro.launch.mesh import make_host_mesh
from repro.launch.train import POFLTrainer, TrainerConfig, run_training
from repro.models.config import InputShape
from repro.optim.optimizers import adamw, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--dmodel", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--policy", default="pofl")
    ap.add_argument("--arch", default="qwen2-0.5b",
                    help="architecture family to scale down")
    args = ap.parse_args()

    cfg = configs.base_config(args.arch)
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.dmodel,
        n_heads=max(4, args.dmodel // 64), n_kv_heads=max(2, args.dmodel // 128),
        d_ff=args.dmodel * 4, vocab_size=4096, tie_embeddings=True,
    )
    print(f"model: {cfg.name} family, {cfg.param_count()/1e6:.1f}M params")

    shape = InputShape("lm", seq_len=args.seq, global_batch=args.batch, kind="train")
    mesh = make_host_mesh(model=1)
    n_fl = mesh.shape["data"]
    print(f"mesh: {dict(mesh.shape)}  ({n_fl} FL devices)")

    trainer = POFLTrainer(
        cfg, shape, mesh,
        TrainerConfig(policy=args.policy, n_scheduled=max(1, n_fl // 2),
                      noise_power=1e-10, stats_mode="sketch", n_probes=2),
        optimizer=adamw(cosine_schedule(3e-4, args.rounds, warmup=10)),
    )

    tokens = make_token_dataset(
        args.batch * 8, args.seq, cfg.vocab_size, jax.random.PRNGKey(0)
    )

    def batch_fn(t):
        idx = jnp.arange(args.batch) + (t * args.batch) % (args.batch * 7)
        return {"tokens": tokens[idx]}

    _, _, losses = run_training(trainer, batch_fn, args.rounds)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not descend"


if __name__ == "__main__":
    main()
