"""Local-update axis tests: the Lemma-2 property battery over multi-step
deltas, the carry-structure (bit-identity) pin, branch-table identities, and
seed-pinned FedProx/FedDyn golden trajectories.

The tentpole claim under test: ``local_update_stage`` replaces the legacy
single gradient with a K-step average effective gradient Δ_i, and the whole
scheduling → Eq. 37 / Horvitz–Thompson reweighting analysis (Lemma 2)
transfers verbatim from gradients to deltas — for EVERY algorithm in
``repro.core.local_update.ALGORITHMS`` × EVERY policy in
``scheduling.POLICY_IDS`` × dropout/churn availability × Dirichlet-sized
shards.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional dev dep (requirements-dev.txt); without it the
# Lemma-2 property test degrades to a derandomized fixed-grid sweep instead
# of skipping the whole battery
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = settings = st = None

from repro.core import POFLConfig, local_update, scheduling
from repro.core.channel import ChannelConfig
from repro.core.local_update import (
    ALGORITHM_IDS,
    ALGORITHMS,
    STATELESS,
    init_state,
    local_gradient_stage,
    local_update_stage,
)
from repro.core.numerics import safe_div
from repro.data import make_classification_dataset
from repro.data.partition import (
    partition_dirichlet_mixed,
    partition_dirichlet_sized,
)
from repro.sim import (
    LatticeSpec,
    SimState,
    cached_engine,
    make_channel_process,
    make_model_task,
    run_lattice,
)

N_DEV, DIM_FEAT = 6, 4  # tiny linear-regression task; flat dim = DIM_FEAT + 1
DIM = DIM_FEAT + 1


def _sq_loss(params, x, y):
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _toy_task(seed, n=N_DEV):
    """Dirichlet-sized regression shards + a small non-zero init."""
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (40 * n, DIM_FEAT))
    y = jax.random.normal(ky, (40 * n,))
    data = partition_dirichlet_sized(
        x, y, n_devices=n, beta=0.4, seed=seed % 100000
    )
    params = {"w": 0.1 * jax.random.normal(kw, (DIM_FEAT,)), "b": jnp.zeros(())}
    return data, params


def _stage(algorithm, local_steps, data, params, key, **cfg_kw):
    cfg = POFLConfig(
        n_devices=data.n_devices, n_scheduled=2, batch_size=4,
        local_algorithm=algorithm, local_steps=local_steps, local_lr=0.05,
        **cfg_kw,
    )
    state = init_state(algorithm, data.n_devices, DIM)
    return local_update_stage(
        _sq_loss, data, cfg, params, key, t=0, alg_state=state
    )


# ------------------------------------------------------------ branch table
def test_algorithm_registry_append_only():
    """ALGORITHM_IDS are lax.switch branch indices — positions are forever
    (same contract as scheduling.POLICY_IDS)."""
    assert ALGORITHMS[:4] == ("fedavg", "fedprox", "feddyn", "scaffold")
    assert [ALGORITHM_IDS[a] for a in ALGORITHMS[:4]] == [0, 1, 2, 3]
    with pytest.raises(ValueError, match="unknown local_algorithm"):
        local_update.algorithm_id("fedsgd")


def test_stateless_carry_is_structurally_legacy():
    """The PR-6 ``None``-subtree trick: stateless algorithms add ZERO leaves
    to the donated scan carry, so the compiled legacy program — and every
    seed-pinned trajectory — is structurally untouched."""
    for name in STATELESS:
        assert init_state(name, 4, 7) is None
    st_dyn = init_state("feddyn", 4, 7)
    assert st_dyn.h.shape == (4, 7) and st_dyn.c is None
    assert len(jax.tree_util.tree_leaves(st_dyn)) == 1
    st_sc = init_state("scaffold", 4, 7)
    assert st_sc.c.shape == (4, 7) and st_sc.h is None
    full = init_state("fedavg", 4, 7, full=True)
    assert full.h.shape == (4, 7) and full.c.shape == (4, 7)

    legacy = SimState(
        params={"w": jnp.zeros(3)}, key=jax.random.PRNGKey(0), chan=jnp.zeros(2)
    )
    leaves, treedef = jax.tree_util.tree_flatten(legacy)
    assert len(leaves) == 3  # params + key + chan; alg=None adds nothing
    explicit = SimState(
        params={"w": jnp.zeros(3)}, key=jax.random.PRNGKey(0),
        chan=jnp.zeros(2), alg=None,
    )
    assert jax.tree_util.tree_structure(explicit) == treedef


def test_engine_carry_matches_algorithm():
    data, params = _toy_task(0)
    cfg = POFLConfig(n_devices=N_DEV, n_scheduled=2, batch_size=4)
    eng = cached_engine(_sq_loss, data, cfg)
    assert eng.init(params, 0).alg is None  # fedavg: the legacy carry
    cfg_dyn = dataclasses.replace(cfg, local_algorithm="feddyn", local_steps=2)
    st_eng = cached_engine(_sq_loss, data, cfg_dyn).init(params, 0)
    assert st_eng.alg.h.shape == (N_DEV, DIM) and st_eng.alg.c is None
    # fused (traced-switch) lattices carry the union of every state field
    st_full = cached_engine(_sq_loss, data, cfg_dyn).init(
        params, 0, fused_algorithms=True
    )
    assert st_full.alg.h.shape == st_full.alg.c.shape == (N_DEV, DIM)


def test_fedavg_single_step_is_the_legacy_gradient_stage():
    """The bit-identity pin: fedavg/fedprox at local_steps=1 ARE the legacy
    one-gradient stage, op for op."""
    data, params = _toy_task(1)
    cfg = POFLConfig(n_devices=N_DEV, n_scheduled=2, batch_size=4)
    k = jax.random.PRNGKey(7)
    delta, new_state = local_update_stage(_sq_loss, data, cfg, params, k, t=0)
    g = local_gradient_stage(_sq_loss, data, cfg, params, k)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(g))
    assert new_state is None
    # fedprox rides the same short-circuit: its proximal term is identically
    # zero on the (only) local step
    cfg_prox = dataclasses.replace(cfg, local_algorithm="fedprox", fedprox_mu=0.5)
    delta_p, _ = local_update_stage(_sq_loss, data, cfg_prox, params, k, t=0)
    np.testing.assert_array_equal(np.asarray(delta_p), np.asarray(g))


def test_branch_identities_at_zero_state():
    """Convergence of branches at degenerate hyperparameters/state:
    fedprox(μ→0) ≡ fedavg at K=3 (the multi-step path, NOT the K=1
    short-circuit), feddyn(h=0) ≡ fedprox(μ=α_d), scaffold(c=0) ≡ fedavg —
    plus the first-round state updates h' = −α_d·drift_K and c' = Δ."""
    data, params = _toy_task(2)
    k = jax.random.PRNGKey(11)
    d_avg, _ = _stage("fedavg", 3, data, params, k)
    d_prox0, _ = _stage("fedprox", 3, data, params, k, fedprox_mu=0.0)
    np.testing.assert_array_equal(np.asarray(d_prox0), np.asarray(d_avg))
    d_prox, _ = _stage("fedprox", 3, data, params, k, fedprox_mu=0.3)
    assert not np.array_equal(np.asarray(d_prox), np.asarray(d_avg))  # μ bites
    d_dyn, st_dyn = _stage("feddyn", 3, data, params, k, feddyn_alpha=0.3)
    np.testing.assert_allclose(
        np.asarray(d_dyn), np.asarray(d_prox), rtol=1e-6, atol=1e-12
    )
    assert np.any(np.asarray(st_dyn.h) != 0.0)  # h' = −α_d (w_K − w0)
    d_sc, st_sc = _stage("scaffold", 3, data, params, k)
    np.testing.assert_allclose(
        np.asarray(d_sc), np.asarray(d_avg), rtol=1e-6, atol=1e-12
    )
    # Option II first round: c' = c − c̄ + Δ = Δ at c = 0
    np.testing.assert_allclose(np.asarray(st_sc.c), np.asarray(d_sc), rtol=1e-6)


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_traced_dispatch_matches_static(algorithm):
    """The lax.switch branch table computes what the static string dispatch
    computes, algorithm by algorithm (the fused lattice's correctness pin at
    the stage level; cross-program tolerance 1e-6)."""
    data, params = _toy_task(3)
    cfg = POFLConfig(
        n_devices=N_DEV, n_scheduled=2, batch_size=4,
        local_algorithm=algorithm, local_steps=2, local_lr=0.05,
        fedprox_mu=0.1, feddyn_alpha=0.2,
    )
    k = jax.random.PRNGKey(13)
    d_static, st_static = local_update_stage(
        _sq_loss, data, cfg, params, k, t=0,
        alg_state=init_state(algorithm, N_DEV, DIM),
    )
    d_traced, st_traced = local_update_stage(
        _sq_loss, data, cfg, params, k, t=0,
        alg_state=init_state(algorithm, N_DEV, DIM, full=True),
        algorithm_id=jnp.asarray(ALGORITHM_IDS[algorithm], jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(d_traced), np.asarray(d_static), rtol=1e-6, atol=1e-12
    )
    if algorithm == "feddyn":
        np.testing.assert_allclose(
            np.asarray(st_traced.h), np.asarray(st_static.h), rtol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(st_traced.c), 0.0)
    elif algorithm == "scaffold":
        np.testing.assert_allclose(
            np.asarray(st_traced.c), np.asarray(st_static.c), rtol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(st_traced.h), 0.0)
    else:
        assert st_static is None  # stateless static path: carry untouched
        np.testing.assert_array_equal(np.asarray(st_traced.h), 0.0)
        np.testing.assert_array_equal(np.asarray(st_traced.c), 0.0)


def test_dispatch_error_contracts():
    data, params = _toy_task(4)
    cfg = POFLConfig(n_devices=N_DEV, local_algorithm="feddyn", local_steps=2)
    k = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="needs per-device AlgState"):
        local_update_stage(_sq_loss, data, cfg, params, k, t=0)
    with pytest.raises(ValueError, match="full=True"):
        local_update_stage(
            _sq_loss, data, cfg, params, k, t=0,
            alg_state=init_state("feddyn", N_DEV, DIM),
            algorithm_id=jnp.asarray(2, jnp.int32),
        )
    with pytest.raises(ValueError, match="local_steps must be >= 1"):
        local_update_stage(
            _sq_loss, data, dataclasses.replace(cfg, local_steps=0),
            params, k, t=0,
            alg_state=init_state("feddyn", N_DEV, DIM),
        )


# -------------------------------------------- Lemma 2 over multi-step deltas
def _check_lemma2(algorithm, policy, seed, scenario, local_steps, task=None):
    """Lemma 2 transfers verbatim from gradients to multi-step deltas:
    conditional on the realized availability mask, BOTH reweighted
    aggregates — the Eq. 37 sequential draw (|S|=1 exact enumeration) and
    the PO-FL-B Horvitz–Thompson variant (analytic mean) — are unbiased for
    the available-population target Σ_{i avail} (m_i/M)·Δ_i, where Δ_i is
    the REAL K-step delta ``local_update_stage`` uploads. Every algorithm ×
    every policy in POLICY_IDS × dropout/churn × dirichlet_sized shards;
    exact expectations, no Monte Carlo.

    ``task`` (a ``repro.sim.tasks.ModelTask``) swaps the toy regression for a
    real dict-pytree model — the deltas are then the RAVELED pytree deltas
    the model-task battery uploads, and the same unbiasedness must hold.
    """
    key = jax.random.PRNGKey(seed)
    k_batch, k_ch, k_roll = jax.random.split(key, 3)

    if task is None:
        data, params = _toy_task(seed % 100000)
        loss_fn, dim = _sq_loss, DIM
    else:
        data, params = task.data, task.params0
        loss_fn, dim = task.loss_fn, task.dim
    n = data.n_devices
    cfg = POFLConfig(
        n_devices=n, n_scheduled=1, batch_size=4,
        local_algorithm=algorithm, local_steps=local_steps, local_lr=0.05,
        fedprox_mu=0.1, feddyn_alpha=0.2,
    )
    delta, _ = local_update_stage(
        loss_fn, data, cfg, params, k_batch, t=0,
        alg_state=init_state(algorithm, n, dim),
    )
    delta = np.asarray(delta)
    assert delta.shape == (n, dim) and np.isfinite(delta).all()

    params_ch = (
        {"p_drop": 0.4} if scenario == "dropout"
        else {"p_depart": 0.3, "p_arrive": 0.3}
    )
    proc = make_channel_process(scenario, ChannelConfig(n_devices=n), **params_ch)
    state = proc.init(k_ch)
    for k in jax.random.split(k_roll, 4):  # roll so the churn chain trends
        state, h, avail = proc.step(state, k)

    # the exact scheduling_stage inputs: uploaded ‖Δ_i‖, shard fractions,
    # realized |h|, then availability masking + renormalization
    frac = jnp.asarray(data.data_frac, jnp.float32)
    norms = jnp.linalg.norm(jnp.asarray(delta, np.float32), axis=1) + 1e-3
    probs = scheduling.scheduling_probs(
        policy, jnp.asarray(norms), jnp.ones(n), jnp.abs(h), frac,
        dim, 0.1, 1.0, 1e-9,
    )
    masked = probs * avail
    probs_a = safe_div(masked, jnp.sum(masked))

    target = np.asarray(
        jnp.sum((avail * frac)[:, None] * jnp.asarray(delta), axis=0)
    )
    if int(avail.sum()) == 0:
        # an all-offline round schedules nothing and weighs nothing
        np.testing.assert_array_equal(np.asarray(probs_a), 0.0)
        return

    # Eq. 37 with |S| = 1: exact enumeration over the (available) draw
    est = np.zeros(dim)
    for i in range(n):
        if float(probs_a[i]) == 0.0:
            continue  # unavailable → never drafted (sampler masks prob 0)
        sched = scheduling.Schedule(
            indices=jnp.array([i], jnp.int32),
            step_probs=probs_a[i][None],
            mask=jnp.zeros(n).at[i].set(1.0),
        )
        rho = scheduling.aggregation_weights(sched, probs_a, frac, 1)
        assert bool(jnp.isfinite(rho).all())
        np.testing.assert_array_equal(
            np.asarray(rho) * (1.0 - np.asarray(avail)), 0.0
        )
        est += float(probs_a[i]) * np.asarray(
            jnp.sum((rho * sched.mask)[:, None] * delta, axis=0)
        )
    np.testing.assert_allclose(est, target, rtol=1e-4, atol=1e-5)

    # Horvitz–Thompson (PO-FL-B): E[mask_i] = π_i, analytic mean over the
    # available set — exact for any |S|
    pi = scheduling.bernoulli_inclusion_probs(
        probs_a, min(2, int(avail.sum()))
    )
    rho_ht = scheduling.bernoulli_weights(pi, frac)
    assert bool(jnp.isfinite(rho_ht).all())
    est_ht = np.asarray(
        jnp.sum((np.asarray(avail) * np.asarray(pi) * np.asarray(rho_ht))[:, None] * delta, axis=0)
    )
    np.testing.assert_allclose(est_ht, target, rtol=1e-3, atol=1e-5)


if st is not None:

    @pytest.mark.parametrize("policy", sorted(scheduling.POLICY_IDS))
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @settings(max_examples=3, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scenario=st.sampled_from(["dropout", "churn"]),
        local_steps=st.integers(1, 3),
    )
    def test_property_lemma2_unbiased_over_multistep_deltas(
        algorithm, policy, seed, scenario, local_steps
    ):
        _check_lemma2(algorithm, policy, seed, scenario, local_steps)

else:

    @pytest.mark.parametrize("policy", sorted(scheduling.POLICY_IDS))
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize(
        "seed,scenario,local_steps", [(0, "dropout", 2), (1, "churn", 3)]
    )
    def test_property_lemma2_unbiased_over_multistep_deltas(
        algorithm, policy, seed, scenario, local_steps
    ):
        _check_lemma2(algorithm, policy, seed, scenario, local_steps)


@pytest.mark.parametrize("policy", sorted(scheduling.POLICY_IDS))
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_lemma2_unbiased_over_model_task_deltas(algorithm, policy):
    """Lemma 2 on REAL model deltas: the uploaded (n, D) matrix is now the
    raveled dict-pytree delta of a logistic-regression task on Dirichlet-sized
    (padded heterogeneous) shards — the exact vectors the model-task battery
    feeds the aggregation stage. Unbiasedness must be model-agnostic: the toy
    quadratic above and the pytree task here share one assertion body."""
    task = make_model_task(
        "logreg", n_devices=6, partition="dirichlet_sized",
        n_train=120, n_test=32, seed=5, dim=16,
    )
    assert task.dim == 16 * 10 + 10  # small D keeps the enumeration cheap
    _check_lemma2(algorithm, policy, seed=7, scenario="dropout",
                  local_steps=2, task=task)


# ------------------------------------------------- seed-pinned goldens
# Regenerate (after an INTENTIONAL semantics change only) by running this
# file's setup below and printing the cell fields — same recipe as
# tests/test_sim.py's churn × dirichlet_mixed golden, with local_steps=2 and
# the algorithm set on the spec. n_scheduled is availability-driven (the
# churn chain rides the cell's channel key), so it is identical across
# algorithms; the metric trajectories diverge from round 0.
GOLDEN_CHURN_MIXED = {
    "fedprox": {
        "n_scheduled": [2.0, 1.0, 4.0, 3.0, 4.0, 4.0],
        "e_com": [0.01768108271062374, 0.0010811339598149061, 0.0118510527536273, 0.015310881659388542, 0.018614666536450386, 0.00744324317201972],
        "e_var": [0.09262384474277496, 0.09879240393638611, 0.05099424719810486, 0.06534551829099655, 0.07145173102617264, 0.0865631252527237],
        "grad_norm": [0.15479350090026855, 0.053263068199157715, 0.1849404126405716, 0.17843686044216156, 0.16058649122714996, 0.11337994039058685],
    },
    "feddyn": {
        "n_scheduled": [2.0, 1.0, 4.0, 3.0, 4.0, 4.0],
        "e_com": [0.01734107919037342, 0.0009688051068224013, 0.009540995582938194, 0.011192893609404564, 0.012434404343366623, 0.004829941317439079],
        "e_var": [0.09069626033306122, 0.08775663375854492, 0.04120548069477081, 0.046483345329761505, 0.049442827701568604, 0.055220939218997955],
        "grad_norm": [0.1533077508211136, 0.050420843064785004, 0.1658255010843277, 0.1541842818260193, 0.13210612535476685, 0.09232784807682037],
    },
}


def _ce_loss(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.mark.parametrize(
    "algorithm,cfg_kw",
    [("fedprox", {"fedprox_mu": 0.1}), ("feddyn", {"feddyn_alpha": 0.3})],
)
def test_seed_pinned_golden_trajectory_churn_mixed(algorithm, cfg_kw):
    """Multi-step (K=2) FedProx/FedDyn trajectories on churn availability ×
    dirichlet_mixed shards are seed-pinned — any drift in the local-update
    scan, the state carry, or the per-step key split shows up here."""
    key = jax.random.PRNGKey(3)
    x, y = make_classification_dataset("mnist_like", 600, key)
    data = partition_dirichlet_mixed(
        x, y, n_devices=10, beta=0.3, beta_size=0.4, seed=0
    )
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}
    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,), seeds=(0,),
        n_rounds=6, algorithms=(algorithm,),
    )
    recs = run_lattice(
        _ce_loss, data, params0, spec,
        base_cfg=POFLConfig(n_devices=10, n_scheduled=4, local_steps=2, **cfg_kw),
        scenario="churn", scenario_params={"p_depart": 0.3, "p_arrive": 0.2},
    )
    exp = GOLDEN_CHURN_MIXED[algorithm]
    np.testing.assert_array_equal(
        np.asarray(recs.n_scheduled[0, 0, 0, 0, 0]),
        np.asarray(exp["n_scheduled"], np.float32),
    )
    for f in ("e_com", "e_var", "grad_norm"):
        np.testing.assert_allclose(
            np.asarray(getattr(recs, f)[0, 0, 0, 0, 0]), exp[f], rtol=1e-5
        )


def test_golden_trajectories_diverge_across_algorithms():
    """The pinned values themselves certify the algorithms do different
    things under identical seeds/availability (same n_scheduled, different
    metrics) — a μ/α_d wired to a dead code path would collapse these."""
    gp, gd = GOLDEN_CHURN_MIXED["fedprox"], GOLDEN_CHURN_MIXED["feddyn"]
    assert gp["n_scheduled"] == gd["n_scheduled"]
    for f in ("e_com", "e_var", "grad_norm"):
        assert gp[f] != gd[f]
