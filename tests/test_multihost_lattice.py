"""Multi-host sharded lattice suite (ISSUE 4 tentpole pin).

Two layers:

  * in-process unit tests (fast, any environment) for the
    ``repro.sim.multihost`` plumbing — env contract, global mesh
    construction, shard assembly, record gathering, npz round-trip, worker
    env hygiene — all of which degrade to single-process behavior in the
    plain pytest process;
  * the ``@pytest.mark.distributed`` subprocess harness: drive
    ``repro.launch.distributed`` to run the parity workload as 2 coordinated
    ``jax.distributed`` processes × 4 fake CPU devices each, and assert the
    gathered records are DTYPE-EXACT against the in-process single-host
    (unsharded, 1-visible-device) run of the same ``LatticeSpec`` (sole
    carve-out: ``e_var``'s documented ≤1-ULP cross-topology codegen wobble —
    see ``_assert_records_equal``) — with zero engine retraces on the
    worker's repeat call (``n_lattice_traces`` guard, checked inside the
    worker where the multi-process trace lives).

The subprocess tests run in the dedicated ``distributed-cpu`` CI job
(``pytest -m distributed``); tier-1 CI deselects them to protect its budget.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.launch.distributed import (
    _RECORD_FIELDS,
    WorkerResult,
    load_records,
    parity_spec,
    run_parity_lattice,
    run_workers,
    save_records,
    worker_env,
)
from repro.sim import multihost
from repro.sim.lattice import make_cell_mesh
HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def _assert_records_equal(a, b, cross_topology: bool = False):
    """Dtype-exact structured equality.

    ``cross_topology=True`` (multi-process vs single-host) relaxes exactly
    ONE field: ``e_var`` — its ‖·‖² reduction over the full parameter dim
    picks up a deterministic ≤1-ULP difference from the process-spanning
    SPMD compilation (measured: 3/48 entries off by 2⁻²⁶ at ~0.1 scale,
    identical on every run; the single-process 8-device mesh is bit-exact,
    pinned by tests/test_lattice_sharded.py). Every other field — including
    the trajectory-critical loss/acc/grad_norm/e_com — must match bit for
    bit, and within one topology repeats are bit-identical (the worker's
    ``repeat_exact`` meta).
    """
    assert a.axes == b.axes
    np.testing.assert_array_equal(a.eval_rounds, b.eval_rounds)
    for f in _RECORD_FIELDS:
        fa, fb = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert fa.shape == fb.shape, f
        assert fa.dtype == fb.dtype, f
        if cross_topology and f == "e_var":
            np.testing.assert_allclose(fa, fb, rtol=1e-6, err_msg=f)
        else:
            np.testing.assert_array_equal(fa, fb, err_msg=f)


# --------------------------------------------------------------------------
# in-process plumbing (single-process degradation paths)
# --------------------------------------------------------------------------


def test_distributed_env_contract(monkeypatch):
    monkeypatch.delenv(multihost.ENV_COORDINATOR, raising=False)
    assert multihost.distributed_env() is None
    monkeypatch.setenv(multihost.ENV_COORDINATOR, "127.0.0.1:1234")
    monkeypatch.setenv(multihost.ENV_NUM_PROCESSES, "2")
    monkeypatch.setenv(multihost.ENV_PROCESS_ID, "1")
    cfg = multihost.distributed_env()
    assert cfg == multihost.DistributedConfig("127.0.0.1:1234", 2, 1)
    # a PARTIAL contract is an operator error, not a silent single-process
    # fallback and not a bare KeyError from inside worker startup
    monkeypatch.delenv(multihost.ENV_NUM_PROCESSES)
    with pytest.raises(ValueError, match="REPRO_DIST_NUM_PROCESSES"):
        multihost.distributed_env()


def test_initialize_noop_without_topology(monkeypatch):
    """No env contract / single-process config → no jax.distributed init."""
    monkeypatch.delenv(multihost.ENV_COORDINATOR, raising=False)
    assert multihost.initialize_distributed() is False
    single = multihost.DistributedConfig("127.0.0.1:1", 1, 0)
    assert multihost.initialize_distributed(single) is False


def test_global_mesh_single_process_equals_local_mesh():
    """With one process the global device list IS the local one, so the two
    mesh constructors agree (and share an engine-cache identity)."""
    from repro.sim.engine import _mesh_key

    g = multihost.make_global_cell_mesh(1)
    l = make_cell_mesh(1)
    assert _mesh_key(g) == _mesh_key(l)
    assert not multihost.mesh_spans_processes(g)
    assert multihost.mesh_process_span(g) == (jax.process_index(),)


def test_global_mesh_validates_device_count():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        multihost.make_global_cell_mesh(n + 1)
    with pytest.raises(ValueError, match="devices"):
        multihost.make_global_cell_mesh(0)


def test_shard_to_global_and_gather_roundtrip():
    """Single-process degradation: assembly is a sliced device_put and the
    gather is a plain device_get — values and dtype survive the round trip."""
    from jax.sharding import NamedSharding, PartitionSpec

    mesh = multihost.make_global_cell_mesh(1)
    sharding = NamedSharding(mesh, PartitionSpec("cells"))
    host = np.arange(6, dtype=np.float32)[:, None] * np.ones((1, 3), np.float32)
    garr = multihost.shard_to_global(host, sharding)
    assert garr.shape == host.shape and garr.is_fully_addressable
    back = multihost.gather_records({"x": garr}, mesh)["x"]
    assert back.dtype == host.dtype
    np.testing.assert_array_equal(np.asarray(back), host)


def test_records_npz_roundtrip(tmp_path):
    recs, meta = run_parity_lattice(mesh=None, n_rounds=2)
    path = str(tmp_path / "recs.npz")
    save_records(path, recs, {"k": 1, **meta})
    loaded, got_meta = load_records(path)
    assert got_meta["k"] == 1 and got_meta["retrace_delta"] == 0
    _assert_records_equal(recs, loaded)


def test_records_npz_contract_drops_optional_subtrees(tmp_path):
    """save_records covers the FLAT array fields only: a records object
    carrying the optional ``eval``/``diag`` pytree subtrees must still save
    readable under np.load's ``allow_pickle=False`` default (a ``None``
    subtree would pickle as an object array; an ``EvalRecord`` would
    collapse into a bare ndarray) and load back with both subtrees ``None``
    — they travel via the in-process/obs paths, never the parity npz."""
    from repro.sim.tasks import EvalRecord

    recs, meta = run_parity_lattice(mesh=None, n_rounds=2)
    curve = np.zeros_like(np.asarray(recs.acc))
    carrying = recs._replace(
        eval=EvalRecord(loss=curve, acc=curve, n_correct=curve)
    )
    path = str(tmp_path / "recs_eval.npz")
    save_records(path, carrying, meta)
    loaded, _ = load_records(path)
    assert loaded.eval is None and loaded.diag is None
    _assert_records_equal(recs, loaded)


def test_worker_env_contract_and_device_pool():
    base = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8 --xla_foo=1",
            "PYTHONPATH": "/elsewhere"}
    env = worker_env("127.0.0.1:9", 2, 1, 4, base_env=base)
    assert env[multihost.ENV_COORDINATOR] == "127.0.0.1:9"
    assert env[multihost.ENV_NUM_PROCESSES] == "2"
    assert env[multihost.ENV_PROCESS_ID] == "1"
    # inherited device-count flag is REPLACED, other XLA flags survive
    assert env["XLA_FLAGS"].count("--xla_force_host_platform_device_count") == 1
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "--xla_foo=1" in env["XLA_FLAGS"]
    assert env["JAX_PLATFORMS"] == "cpu"
    parts = env["PYTHONPATH"].split(os.pathsep)
    assert SRC in parts and "/elsewhere" in parts


def test_run_workers_raises_on_any_failure():
    """The launcher must not report success over a half-failed topology."""
    argv = [
        sys.executable, "-c",
        "import os, sys; sys.exit(3 if os.environ['REPRO_DIST_PROCESS_ID'] == '1' else 0)",
    ]
    with pytest.raises(RuntimeError, match="worker 1"):
        run_workers(argv, n_procs=2, devices_per_proc=1, timeout=60)


def test_engine_cache_key_includes_process_topology():
    from repro.sim.engine import _process_topology_key

    assert _process_topology_key() == (jax.process_count(), jax.process_index())


# --------------------------------------------------------------------------
# the subprocess-driven 2-process × 4-fake-device parity harness
# --------------------------------------------------------------------------


@pytest.mark.distributed
def test_two_process_lattice_matches_single_host(tmp_path):
    """ISSUE 4 acceptance: drive the launcher CLI via subprocess — 2
    coordinated processes × 4 fake CPU devices run the parity LatticeSpec on
    a process-spanning global mesh — and compare the worker-0 records
    DTYPE-EXACTLY against the in-process single-host (unsharded) run of the
    same spec. Worker meta must prove the topology was real (2 processes, 8
    global / 4 local devices) and that the repeat call re-traced ZERO times.
    """
    out = str(tmp_path / "parity.npz")
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    # the launcher's own worker deadline (450s) must trip BEFORE the outer
    # timeout (600s): the launcher then reaps its workers and reports their
    # output tails, instead of being killed around still-running grandchildren
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.distributed",
         "--procs", "2", "--devices-per-proc", "4",
         "--workload", "parity", "--out", out, "--timeout", "450"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    if proc.returncode != 0:
        sys.stdout.write(proc.stdout[-8000:])
        sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed launcher failed"

    sharded, meta = load_records(out)
    assert meta["process_count"] == 2
    assert meta["n_global_devices"] == 8
    assert meta["n_local_devices"] == 4
    # zero retraces on the repeat sharded call, and bit-stable repeat records
    assert meta["retrace_delta"] == 0
    assert meta["repeat_exact"] is True
    # the policy-FUSED lattice: the whole 2-policy spec is one trace / one
    # compile inside the worker topology, and the per-policy fallback
    # reproduces it bit for bit across the process boundary
    assert meta["traces_first"] == 1
    assert meta["n_lattice_compiles"] == 1
    assert meta["fused_matches_fallback"] is True

    reference, ref_meta = run_parity_lattice(mesh=None)
    assert ref_meta["retrace_delta"] == 0
    _assert_records_equal(reference, sharded, cross_topology=True)

    # the parity grid must exercise dead-cell padding across the process
    # boundary: 6 real cells per policy on an 8-device global mesh
    spec = parity_spec()
    n_grid = len(spec.noise_powers) * len(spec.alphas) * len(spec.seeds)
    assert n_grid == 6 and meta["n_global_devices"] == 8


@pytest.mark.distributed
def test_launcher_generic_command_mode(tmp_path):
    """`-- command` mode: any script that initializes from the env contract
    runs under the launcher (here: examples/sim_lattice.py --distributed)."""
    example = os.path.abspath(os.path.join(HERE, "..", "examples", "sim_lattice.py"))
    results = run_workers(
        [sys.executable, example, "--distributed", "--rounds", "2"],
        n_procs=2, devices_per_proc=2, timeout=600,
    )
    assert all(isinstance(r, WorkerResult) and r.returncode == 0 for r in results)
    assert "cells sharded over 4 devices (2 hosts)" in results[0].output
