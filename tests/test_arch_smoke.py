"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate a REDUCED variant of the same
family (2 layers, d_model ≤ 512, ≤ 4 experts) and run one forward/train step
on CPU asserting output shapes + no NaNs; plus one prefill→decode step for
the decode-capable archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import api
from repro.models.config import INPUT_SHAPES

SMOKE_B, SMOKE_S = 2, 32


def _smoke_batch(cfg, key, seq=SMOKE_S):
    kt, ke = jax.random.split(key)
    batch = {}
    if cfg.arch_type == "vlm":
        n_p = cfg.vlm.n_patches
        batch["tokens"] = jax.random.randint(kt, (SMOKE_B, seq - n_p), 0, cfg.vocab_size)
        batch["embeds"] = jax.random.normal(ke, (SMOKE_B, n_p, cfg.d_model))
    elif cfg.arch_type == "encdec":
        batch["tokens"] = jax.random.randint(kt, (SMOKE_B, seq), 0, cfg.vocab_size)
        batch["frames"] = jax.random.normal(
            ke, (SMOKE_B, cfg.encdec.n_enc_frames, cfg.d_model)
        )
    else:
        batch["tokens"] = jax.random.randint(kt, (SMOKE_B, seq), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch_id", configs.ARCH_IDS)
def test_reduced_train_step(arch_id):
    cfg = configs.reduced_config(arch_id)
    key = jax.random.PRNGKey(0)
    params = api.model_init(cfg, key)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, _ = api.model_loss(p, cfg, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss {loss}"
    # gradient flows to every parameter leaf
    leaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(l)) for l in leaves), f"{arch_id}: NaN grads"
    total_norm = sum(jnp.sum(l * l) for l in leaves) ** 0.5
    assert total_norm > 0, f"{arch_id}: zero gradient"
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2, _ = api.model_loss(params2, cfg, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch_id", configs.ARCH_IDS)
def test_reduced_prefill_decode(arch_id):
    cfg = configs.reduced_config(arch_id)
    key = jax.random.PRNGKey(0)
    params = api.model_init(cfg, key)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits, cache = api.model_prefill(params, cfg, batch)
    assert logits.shape == (SMOKE_B, 1, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(logits)), f"{arch_id}: NaN prefill logits"

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    seq = batch["tokens"].shape[1]
    t = jnp.asarray(seq, jnp.int32)
    logits2, cache2 = api.model_decode(params, cfg, tok, cache, t)
    assert logits2.shape == (SMOKE_B, 1, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(logits2)), f"{arch_id}: NaN decode logits"


@pytest.mark.parametrize("arch_id", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full-size config matches the assigned numbers exactly."""
    cfg = configs.get_config(arch_id)
    expected = {
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "mamba2-370m": (48, 1024, 16, 16, 0, 50280),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch_id}: {got} != {expected}"
    assert cfg.source, f"{arch_id}: missing source citation"
    # MoE / SSM extras
    if arch_id == "olmoe-1b-7b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (64, 8)
    if arch_id == "llama4-scout-17b-a16e":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 1)
    if arch_id == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64
    if arch_id == "mamba2-370m":
        assert cfg.ssm.d_state == 128


def test_long_context_variants():
    for a in configs.LONG_CONTEXT_VIA_WINDOW:
        cfg = configs.get_config(a, "long_500k")
        assert cfg.sliding_window == configs.LONG_CONTEXT_WINDOW
        assert cfg.supports_long_context
    for a in ("zamba2-2.7b", "mamba2-370m"):
        cfg = configs.get_config(a, "long_500k")
        assert cfg.supports_long_context  # native, no window needed
    for a in configs.LONG_CONTEXT_SKIP:
        with pytest.raises(ValueError):
            configs.get_config(a, "long_500k")
        assert not configs.supports_shape(a, "long_500k")


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_are_structs(shape_name):
    """input_specs never allocates — everything is a ShapeDtypeStruct."""
    for arch_id in configs.ARCH_IDS:
        if not configs.supports_shape(arch_id, shape_name):
            continue
        cfg = configs.get_config(arch_id, shape_name)
        specs = configs.input_specs(cfg, shape_name)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
        shape = INPUT_SHAPES[shape_name]
        if shape.kind in ("train", "prefill"):
            toks = specs["batch"]["tokens"]
            assert toks.shape[0] == shape.global_batch
        else:
            assert specs["token"].shape == (shape.global_batch, 1)
