"""benchmarks/run.py CLI topology guards (ISSUE 4 satellite).

A ``--mesh N`` the machine cannot honor used to surface only as a
``CSV,sim_lattice,...,ERROR:...`` line while every other benchmark ran and
no ``BENCH_sim.json`` was written — a silent fallback. The guards now abort
the whole run with exit code 2 before any benchmark executes.
"""
from __future__ import annotations

import os
import sys

import jax
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks import run as bench_run  # noqa: E402


def _error_code(argv):
    with pytest.raises(SystemExit) as exc:
        bench_run.main(argv)
    return exc.value.code


def test_mesh_exceeding_local_devices_is_hard_error(capsys):
    n_local = len(jax.devices())
    assert _error_code(["--mesh", str(n_local + 1)]) == 2
    err = capsys.readouterr().err
    assert f"needs {n_local + 1} devices but only {n_local}" in err
    assert "xla_force_host_platform_device_count" in err


def test_mesh_2d_syntax_guards(capsys):
    n_local = len(jax.devices())
    # CxM needing more devices than visible: same hard error
    assert _error_code(["--mesh", f"{n_local + 1}x1"]) == 2
    assert "xla_force_host_platform_device_count" in capsys.readouterr().err
    # malformed CxM strings are parser errors, not tracebacks
    assert _error_code(["--mesh", "4x"]) == 2
    assert _error_code(["--mesh", "ax2"]) == 2
    assert _error_code(["--mesh", "4x0"]) == 2
    # model sharding is single-host only
    assert _error_code(["--hosts", "2", "--mesh", "4x2"]) == 2
    assert "single-host" in capsys.readouterr().err


def test_mesh_within_local_devices_passes_guard(monkeypatch):
    """A satisfiable --mesh must NOT trip the guard (the guard may only fire
    on impossible topologies). The benchmarks themselves are stubbed out."""
    monkeypatch.setattr(bench_run, "_run", lambda *a, **k: None)
    bench_run.main(["--mesh", str(len(jax.devices()))])  # no SystemExit


def test_hosts_must_be_positive():
    assert _error_code(["--hosts", "0"]) == 2


def test_mesh_must_divide_across_hosts(capsys):
    assert _error_code(["--hosts", "3", "--mesh", "4"]) == 2
    assert "divide evenly" in capsys.readouterr().err


def test_negative_mesh_rejected():
    assert _error_code(["--mesh", "-2"]) == 2


def test_unknown_algorithm_is_hard_error(capsys):
    assert _error_code(["--algorithms", "fedavg,fedsgd"]) == 2
    err = capsys.readouterr().err
    assert "unknown algorithm" in err and "fedsgd" in err


def test_empty_algorithm_name_is_hard_error():
    assert _error_code(["--algorithms", "fedavg,,fedprox"]) == 2
    assert _error_code(["--algorithms", ""]) == 2


def test_local_steps_must_be_positive():
    assert _error_code(["--local-steps", "0"]) == 2


def test_algorithm_axis_is_single_host_only(capsys):
    assert _error_code(["--hosts", "2", "--algorithms", "fedavg,fedprox"]) == 2
    assert "single-host" in capsys.readouterr().err
    assert _error_code(["--hosts", "2", "--local-steps", "3"]) == 2


def test_valid_algorithm_axis_passes_guard(monkeypatch):
    """A well-formed multi-algorithm sweep must NOT trip the guards (the
    benchmarks themselves are stubbed out)."""
    monkeypatch.setattr(bench_run, "_run", lambda *a, **k: None)
    bench_run.main(["--algorithms", "fedavg,fedprox", "--local-steps", "2"])


def test_task_cli_guards(capsys, monkeypatch):
    """--task guards (ISSUE 9 satellite): the CNN's input shape is fixed
    (no --dim) and it is single-host only; unknown names are parser errors;
    a well-formed --task cnn passes the guards."""
    assert _error_code(["--task", "cnn", "--dim", "64"]) == 2
    assert "--dim only applies to the logreg task" in capsys.readouterr().err
    assert _error_code(["--task", "cnn", "--hosts", "2"]) == 2
    assert "single-host" in capsys.readouterr().err
    assert _error_code(["--task", "mlp"]) == 2
    monkeypatch.setattr(bench_run, "_run", lambda *a, **k: None)
    bench_run.main(["--task", "cnn"])  # no SystemExit


def test_bench_task_rejects_dim_for_cifar():
    """Direct (non-CLI) callers get a hard error, not a silent no-op: the
    CNN's input shape is fixed by its architecture, so a ``dim`` override
    with ``kind='cifar'`` must raise instead of being dropped on the floor
    (the CLI guard above only protects ``--task cnn --dim``)."""
    from benchmarks.common import bench_task

    with pytest.raises(ValueError, match="dim override"):
        bench_task(dim=64, kind="cifar")


def test_gate_key_splits_on_task():
    """The perf gate never compares across model tasks: a CNN entry with an
    otherwise-identical topology passes trivially against logreg history
    (and legacy entries WITHOUT the field only match each other)."""
    from benchmarks.report import _gate_key, gate_regression

    base = dict(backend="jnp", mesh_shape=None, mesh_devices=1, n_hosts=1,
                dim=7850, cells=8, n_rounds=10, steady_cells_per_sec=10.0)
    logreg = dict(base, task="logreg")
    cnn = dict(base, task="cnn", dim=258634)
    legacy = dict(base)  # pre-model-task history: no `task` field
    assert _gate_key(logreg) != _gate_key(cnn)
    assert _gate_key(legacy) != _gate_key(logreg)

    ok, msg = gate_regression([logreg, dict(cnn, steady_cells_per_sec=0.1)])
    assert ok and "no prior entry" in msg
    # same task DOES compare (and a 99% drop fails the gate)
    ok, _ = gate_regression(
        [logreg, dict(logreg, steady_cells_per_sec=0.1)]
    )
    assert not ok
