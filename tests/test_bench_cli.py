"""benchmarks/run.py CLI topology guards (ISSUE 4 satellite).

A ``--mesh N`` the machine cannot honor used to surface only as a
``CSV,sim_lattice,...,ERROR:...`` line while every other benchmark ran and
no ``BENCH_sim.json`` was written — a silent fallback. The guards now abort
the whole run with exit code 2 before any benchmark executes.
"""
from __future__ import annotations

import os
import sys

import jax
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks import run as bench_run  # noqa: E402


def _error_code(argv):
    with pytest.raises(SystemExit) as exc:
        bench_run.main(argv)
    return exc.value.code


def test_mesh_exceeding_local_devices_is_hard_error(capsys):
    n_local = len(jax.devices())
    assert _error_code(["--mesh", str(n_local + 1)]) == 2
    err = capsys.readouterr().err
    assert f"needs {n_local + 1} devices but only {n_local}" in err
    assert "xla_force_host_platform_device_count" in err


def test_mesh_2d_syntax_guards(capsys):
    n_local = len(jax.devices())
    # CxM needing more devices than visible: same hard error
    assert _error_code(["--mesh", f"{n_local + 1}x1"]) == 2
    assert "xla_force_host_platform_device_count" in capsys.readouterr().err
    # malformed CxM strings are parser errors, not tracebacks
    assert _error_code(["--mesh", "4x"]) == 2
    assert _error_code(["--mesh", "ax2"]) == 2
    assert _error_code(["--mesh", "4x0"]) == 2
    # model sharding is single-host only
    assert _error_code(["--hosts", "2", "--mesh", "4x2"]) == 2
    assert "single-host" in capsys.readouterr().err


def test_mesh_within_local_devices_passes_guard(monkeypatch):
    """A satisfiable --mesh must NOT trip the guard (the guard may only fire
    on impossible topologies). The benchmarks themselves are stubbed out."""
    monkeypatch.setattr(bench_run, "_run", lambda *a, **k: None)
    bench_run.main(["--mesh", str(len(jax.devices()))])  # no SystemExit


def test_hosts_must_be_positive():
    assert _error_code(["--hosts", "0"]) == 2


def test_mesh_must_divide_across_hosts(capsys):
    assert _error_code(["--hosts", "3", "--mesh", "4"]) == 2
    assert "divide evenly" in capsys.readouterr().err


def test_negative_mesh_rejected():
    assert _error_code(["--mesh", "-2"]) == 2


def test_unknown_algorithm_is_hard_error(capsys):
    assert _error_code(["--algorithms", "fedavg,fedsgd"]) == 2
    err = capsys.readouterr().err
    assert "unknown algorithm" in err and "fedsgd" in err


def test_empty_algorithm_name_is_hard_error():
    assert _error_code(["--algorithms", "fedavg,,fedprox"]) == 2
    assert _error_code(["--algorithms", ""]) == 2


def test_local_steps_must_be_positive():
    assert _error_code(["--local-steps", "0"]) == 2


def test_algorithm_axis_is_single_host_only(capsys):
    assert _error_code(["--hosts", "2", "--algorithms", "fedavg,fedprox"]) == 2
    assert "single-host" in capsys.readouterr().err
    assert _error_code(["--hosts", "2", "--local-steps", "3"]) == 2


def test_valid_algorithm_axis_passes_guard(monkeypatch):
    """A well-formed multi-algorithm sweep must NOT trip the guards (the
    benchmarks themselves are stubbed out)."""
    monkeypatch.setattr(bench_run, "_run", lambda *a, **k: None)
    bench_run.main(["--algorithms", "fedavg,fedprox", "--local-steps", "2"])
