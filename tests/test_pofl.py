"""End-to-end PO-FL simulator tests (Algorithm 1) + paper-claim validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig, run_pofl
from repro.data import make_classification_dataset, partition_noniid_shards


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 3000, key)
    xt, yt = make_classification_dataset("mnist_like", 600, jax.random.PRNGKey(1))
    data = partition_noniid_shards(x, y, n_devices=20)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    @jax.jit
    def ev(p):
        logits = xt @ p["w"] + p["b"]
        return _loss_fn(p, xt, yt), jnp.mean(jnp.argmax(logits, -1) == yt)

    return data, params0, ev


def _run(setup, policy, rounds=40, noise=1e-10, sampler="without_replacement", **kw):
    data, params0, ev = setup
    cfg = POFLConfig(
        n_devices=20, n_scheduled=8, policy=policy, noise_power=noise,
        sampler=sampler, **kw,
    )
    return run_pofl(_loss_fn, params0, data, cfg, rounds, eval_fn=ev, eval_every=rounds - 1)


def test_pofl_learns(setup):
    _, hist = _run(setup, "pofl")
    assert hist.test_acc[-1] > 0.85, hist.test_acc


def test_policy_ordering_matches_paper(setup):
    """Paper Figs. 3–5: channel-aware fails; PO-FL ≳ importance; noise-free
    is the upper bound. Validated at elevated noise where separation is clear."""
    accs = {}
    for policy in ["pofl", "importance", "channel", "noisefree"]:
        _, hist = _run(setup, policy, rounds=40, noise=3e-10)
        accs[policy] = hist.test_acc[-1]
    assert accs["noisefree"] >= accs["pofl"] - 0.05
    assert accs["pofl"] > accs["channel"] + 0.1
    assert accs["importance"] > accs["channel"]


def test_pofl_beats_importance_at_high_noise(setup):
    """Paper Fig. 5 noise-limited regime: PO-FL's channel term matters.
    Averaged over seeds (single-run FL accuracy is noisy)."""
    acc = {"pofl": [], "importance": []}
    ecom = {"pofl": [], "importance": []}
    for policy in acc:
        for seed in range(3):
            _, h = _run(setup, policy, rounds=40, noise=3e-9, seed=seed)
            acc[policy].append(h.test_acc[-1])
            ecom[policy].append(np.mean(h.e_com))
    assert np.mean(acc["pofl"]) > np.mean(acc["importance"]) + 0.05
    assert np.mean(ecom["pofl"]) < np.mean(ecom["importance"])


def test_ecom_decreases_with_noise_power(setup):
    _, h_low = _run(setup, "pofl", rounds=10, noise=1e-12)
    _, h_high = _run(setup, "pofl", rounds=10, noise=1e-10)
    assert np.mean(h_low.e_com) < np.mean(h_high.e_com)


def test_bernoulli_sampler_works(setup):
    _, hist = _run(setup, "pofl", sampler="bernoulli")
    assert hist.test_acc[-1] > 0.85


def test_physical_path_equivalent_training(setup):
    data, params0, ev = setup
    cfg_a = POFLConfig(n_devices=20, n_scheduled=8, policy="pofl", simulate_physical=True)
    p_a, h_a = run_pofl(_loss_fn, params0, data, cfg_a, 15, eval_fn=ev, eval_every=14)
    assert h_a.test_acc[-1] > 0.5  # the full Eq.5→8 chain also trains


def test_reproducible_given_seed(setup):
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=20, n_scheduled=5, policy="pofl", seed=123)
    p1, _ = run_pofl(_loss_fn, params0, data, cfg, 5)
    p2, _ = run_pofl(_loss_fn, params0, data, cfg, 5)
    np.testing.assert_array_equal(p1["w"], p2["w"])
