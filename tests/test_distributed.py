"""Distributed PO-FL trainer correctness on a small host mesh.

Key invariant (DESIGN.md §5): the fused per-example-weight backward equals
the explicit PO-FL aggregate Σ_i c_i·g_i computed from per-device gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    build_prefill_step,
    build_serve_step,
    build_stats_step,
    build_train_step,
)
from repro.models import api
from repro.models.config import InputShape
from repro.optim.optimizers import sgd

SMALL_TRAIN = InputShape("small_train", seq_len=32, global_batch=8, kind="train")
SMALL_DECODE = InputShape("small_decode", seq_len=64, global_batch=8, kind="decode")
SMALL_PREFILL = InputShape("small_prefill", seq_len=32, global_batch=8, kind="prefill")


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under pytest with default 1? no)")
    return make_host_mesh(model=1)


def _cfg():
    return configs.reduced_config("qwen2-0.5b")


def _batch(cfg, shape, key):
    return {
        "tokens": jax.random.randint(
            key, (shape.global_batch, shape.seq_len), 0, cfg.vocab_size
        )
    }


def test_fused_weighted_backward_equals_pofl_aggregate(mesh):
    """Σ_i c_i · g_i  ==  grad of mean(per-example-weighted loss)."""
    cfg = _cfg()
    n_fl = mesh.shape["data"]
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, SMALL_TRAIN, jax.random.PRNGKey(1))
    b = SMALL_TRAIN.global_batch
    coeffs = jax.random.uniform(jax.random.PRNGKey(7), (n_fl,), minval=0.0, maxval=1.5)
    coeffs = coeffs.at[1].set(0.0)  # one unscheduled device

    # reference: explicit per-device gradients
    per_dev = b // n_fl

    def dev_loss(p, d):
        sl = {k: jax.lax.dynamic_slice_in_dim(v, d * per_dev, per_dev) for k, v in batch.items()}
        loss, _ = api.model_loss(p, cfg, sl, aux_coeff=0.0)
        return loss

    ref = None
    for d in range(n_fl):
        g = jax.grad(lambda p: dev_loss(p, d))(params)
        g = jax.tree.map(lambda x: coeffs[d] * x, g)
        ref = g if ref is None else jax.tree.map(jnp.add, ref, g)

    # fused: per-example weights c_d·n_fl
    w = jnp.repeat(coeffs * n_fl, per_dev)

    def fused_loss(p):
        loss, _ = api.model_loss(p, cfg, batch, loss_weights=w, aux_coeff=0.0)
        return loss

    got = jax.grad(fused_loss)(params)
    for r, g in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(r), np.asarray(g), rtol=2e-4, atol=2e-5)


def test_train_step_runs_and_descends(mesh):
    cfg = _cfg()
    bundle = build_train_step(
        cfg, SMALL_TRAIN, mesh, sgd(0.05), dtype=jnp.float32, aircomp_noise=True
    )
    n_fl = mesh.shape["data"]
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, bundle.in_shardings["params"])
    opt_state = sgd(0.05).init(params)
    batch = _batch(cfg, SMALL_TRAIN, jax.random.PRNGKey(1))
    coeffs = jnp.ones((n_fl,)) / n_fl * n_fl  # full participation, uniform
    noise_amp = jnp.float32(0.0)
    key = jax.random.PRNGKey(2)

    losses = []
    for t in range(5):
        params, opt_state, loss = bundle.fn(
            params, opt_state, batch, coeffs, noise_amp, jax.random.fold_in(key, t)
        )
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_serve_step_matches_unsharded_decode(mesh):
    cfg = _cfg()
    bundle = build_serve_step(cfg, SMALL_DECODE, mesh, dtype=jnp.float32)
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    b, s = SMALL_DECODE.global_batch, SMALL_DECODE.seq_len

    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, 16), 0, cfg.vocab_size)}
    logits, cache = api.model_prefill(params, cfg, prompt, jnp.float32)
    from repro.models.cache import pad_cache

    cache = pad_cache(cache, s)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    # unsharded reference decode
    ref_logits, _ = api.model_decode(
        params, cfg, tok, cache, jnp.asarray(16, jnp.int32), jnp.float32
    )
    ref_next = jnp.argmax(ref_logits[:, -1], axis=-1)

    p_sh = jax.device_put(params, bundle.in_shardings["params"])
    c_sh = jax.device_put(cache, bundle.in_shardings["cache"])
    got_next, _ = bundle.fn(p_sh, tok, c_sh, jnp.asarray(16, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got_next[:, 0]), np.asarray(ref_next))


def test_prefill_step_sharded(mesh):
    cfg = _cfg()
    bundle = build_prefill_step(cfg, SMALL_PREFILL, mesh, dtype=jnp.float32)
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, bundle.in_shardings["params"])
    batch = _batch(cfg, SMALL_PREFILL, jax.random.PRNGKey(1))
    logits, cache = bundle.fn(params, batch)
    assert logits.shape == (SMALL_PREFILL.global_batch, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_stats_step_sketch_close_to_exact(mesh):
    """JVP-sketched stats: the sharded M_i matches the single-host JVP
    tightly (the sharding/remat machinery adds no error); vs the reverse-mode
    gradient mean only a scale-anchored bound holds (forward- and
    reverse-mode float32 rounding diverge at the mean's cancellation-
    dominated ~1e-5 scale); ‖g_i‖ unbiased (loose tolerance)."""
    cfg = _cfg()
    bundle = build_stats_step(
        cfg, SMALL_TRAIN, mesh, dtype=jnp.float32, n_probes=48
    )
    n_fl = mesh.shape["data"]
    params_host = api.model_init(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params_host, bundle.in_shardings["params"])
    batch = _batch(cfg, SMALL_TRAIN, jax.random.PRNGKey(1))
    mean, var, norm = bundle.fn(params, batch, jax.random.PRNGKey(3))

    # single-host reference for the SAME forward-mode statistic — sharp:
    # catches any sharding-induced scaling (e.g. a stray psum-mean)
    b = SMALL_TRAIN.global_batch
    per_dev = b // n_fl

    def per_device_loss(p):
        pe, _ = api.model_loss(p, cfg, batch, dtype=jnp.float32, reduce=False)
        return pe.reshape(n_fl, per_dev).mean(axis=1)

    ones = jax.tree.map(jnp.ones_like, params_host)
    _, dots = jax.jvp(per_device_loss, (params_host,), (ones,))
    dim = sum(int(jnp.size(l)) for l in jax.tree.leaves(params_host))
    np.testing.assert_allclose(
        np.asarray(mean), np.asarray(dots / dim), rtol=1e-3, atol=1e-9
    )

    # exact (reverse-mode) per-device gradients
    for d in range(n_fl):
        sl = {k: v[d * per_dev:(d + 1) * per_dev] for k, v in batch.items()}

        def dl(p):
            pe, _ = api.model_loss(p, cfg, sl, reduce=False)
            return pe.mean()

        g = jax.grad(dl)(params)
        flat = jnp.concatenate([l.ravel() for l in jax.tree.leaves(g)])
        # forward vs reverse mode agree only to the float32 noise floor of
        # the gradient-entry RMS scale, which the ~1e-5 mean sits below
        rms = float(jnp.linalg.norm(flat)) / np.sqrt(flat.size)
        assert abs(float(mean[d]) - float(flat.mean())) < 5e-3 * rms + 1e-9
        # Hutchinson: relative error ~ sqrt(2/k) ≈ 0.2 at k=48
        assert abs(float(norm[d]) - float(jnp.linalg.norm(flat))) \
            < 0.5 * float(jnp.linalg.norm(flat)) + 1e-9
