"""2-D (cells × model) mesh parity/property suite (ISSUE 7 tentpole pins).

Contracts pinned here:

  * a ``(C, 1)`` 2-D mesh is BIT-IDENTICAL to the existing 1-D
    ``P("cells")`` path — the model axis at size 1 must not perturb the
    traced program (``ModelShard`` only engages at |model| > 1);
  * a 4×2 mesh on 8 fake CPU devices matches the unsharded run with
    ``n_scheduled``/``loss``/``acc`` exactly equal and the float error
    channels (``e_com``/``e_var``/``grad_norm``) within float32 reduction
    tolerance — measured ~6e-7 max rel; the psum'd Eq. 5 statistics cross
    program shapes, so ≤1-ULP-per-reduction drift is expected and pinned
    at rtol 1e-5.  Both the ``jnp`` and ``pallas_fused`` (interpret)
    backends are covered;
  * repeat 2-D sweeps re-trace ZERO times and the fused sweep compiles
    exactly ONE program (``n_compiles == 1``);
  * engine-cache keys distinguish 2-D-meshed engines from 1-D and
    unmeshed ones.

The multi-device legs run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the dedicated CI
job) and skip when fewer devices are visible.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig
from repro.data import make_classification_dataset, partition_noniid_shards
from repro.sim import (
    FUSED_POLICY,
    LatticeRecords,
    LatticeSpec,
    cached_engine,
    make_cell_mesh,
    make_cell_model_mesh,
    run_lattice,
)

N_VISIBLE = len(jax.devices())
needs_8_devices = pytest.mark.skipif(
    N_VISIBLE < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

_RECORD_FIELDS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")
# fields that must stay EXACT across sharding (integers / argmax decisions)
_EXACT_FIELDS = ("n_scheduled", "loss", "acc")
# float channels whose reductions cross program shapes under model sharding
_FLOAT_FIELDS = ("e_com", "e_var", "grad_norm")


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_noniid_shards(x, y, n_devices=8)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def ev(p):
        logits = x[:200] @ p["w"] + p["b"]
        return _loss_fn(p, x[:200], y[:200]), jnp.mean(
            jnp.argmax(logits, -1) == y[:200]
        )

    return data, params0, ev


def _assert_records_equal(a: LatticeRecords, b: LatticeRecords):
    """Dtype-exact equality of the full structured output, order included."""
    assert a.axes == b.axes
    np.testing.assert_array_equal(a.eval_rounds, b.eval_rounds)
    for f in _RECORD_FIELDS:
        fa, fb = getattr(a, f), getattr(b, f)
        assert fa.shape == fb.shape, f
        assert fa.dtype == fb.dtype, f
        np.testing.assert_array_equal(fa, fb, err_msg=f)


def _assert_records_close(a: LatticeRecords, b: LatticeRecords, rtol=1e-5):
    """Model-sharded parity: decisions exact, float channels within
    float32 cross-shape-reduction tolerance (measured ~6e-7 max rel)."""
    assert a.axes == b.axes
    np.testing.assert_array_equal(a.eval_rounds, b.eval_rounds)
    for f in _EXACT_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    for f in _FLOAT_FIELDS:
        np.testing.assert_allclose(
            getattr(a, f), getattr(b, f), rtol=rtol, atol=1e-12, err_msg=f
        )


def _sweep(setup, mesh, spec=None, **cfg_kw):
    data, params0, ev = setup
    spec = spec or LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        seeds=(0, 1000),
        n_rounds=4,
        eval_every=2,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3, **cfg_kw)
    return run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )


# --------------------------------------------------------------------------
# mesh constructor contract
# --------------------------------------------------------------------------


def test_make_cell_model_mesh_shapes_and_validation():
    m = make_cell_model_mesh(1, 1)
    assert m.axis_names == ("cells", "model")
    assert dict(m.shape) == {"cells": 1, "model": 1}
    with pytest.raises(ValueError, match="model"):
        make_cell_model_mesh(1, 0)
    with pytest.raises(ValueError, match="devices"):
        make_cell_model_mesh(N_VISIBLE + 1, 1)
    if N_VISIBLE >= 2:
        m = make_cell_model_mesh(None, 2)  # cells inferred from devices
        assert dict(m.shape)["model"] == 2
        assert dict(m.shape)["cells"] == N_VISIBLE // 2


def test_run_lattice_tuple_shorthand(setup):
    """``mesh=(C, M)`` is sugar for ``mesh=make_cell_model_mesh(C, M)``."""
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1), n_rounds=3)
    by_tuple = _sweep(setup, mesh=(1, 1), spec=spec)
    by_mesh = _sweep(setup, mesh=make_cell_model_mesh(1, 1), spec=spec)
    _assert_records_equal(by_tuple, by_mesh)


# --------------------------------------------------------------------------
# (C, 1) degenerate model axis: bit-identical to the 1-D path
# --------------------------------------------------------------------------


def test_c_by_1_mesh_bit_identical_to_1d(setup):
    """Acceptance pin: a (C,1) 2-D mesh traces the SAME program as the 1-D
    P("cells") mesh — records bit-identical."""
    c = min(8, N_VISIBLE)
    one_d = _sweep(setup, mesh=make_cell_mesh(c))
    two_d = _sweep(setup, mesh=make_cell_model_mesh(c, 1))
    _assert_records_equal(one_d, two_d)


# --------------------------------------------------------------------------
# engine-cache keying
# --------------------------------------------------------------------------


def test_cache_keys_distinguish_2d_meshes(setup):
    data, _, _ = setup
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    plain = cached_engine(_loss_fn, data, cfg)
    one_d = cached_engine(_loss_fn, data, cfg, mesh=make_cell_mesh(1))
    c1 = cached_engine(_loss_fn, data, cfg, mesh=make_cell_model_mesh(1, 1))
    assert c1 is not plain and c1 is not one_d
    # a fresh equal 2-D mesh resolves to the SAME engine
    assert (
        cached_engine(_loss_fn, data, cfg, mesh=make_cell_model_mesh(1, 1))
        is c1
    )
    if N_VISIBLE >= 2:
        m12 = cached_engine(
            _loss_fn, data, cfg, mesh=make_cell_model_mesh(1, 2)
        )
        assert m12 is not c1


# --------------------------------------------------------------------------
# model-sharded semantics on 8 fake devices (4 cells × 2 model shards)
# --------------------------------------------------------------------------


@needs_8_devices
@pytest.mark.parametrize("backend", ["jnp", "pallas_fused"])
def test_4x2_mesh_matches_unsharded(setup, backend, monkeypatch):
    """Acceptance pin: the 4×2 model-sharded run matches the unsharded run —
    decisions exact, float error channels within reduction tolerance — for
    BOTH aggregation backends (pallas in interpret mode on CPU)."""
    if backend == "pallas_fused":
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    unsharded = _sweep(setup, mesh=None, backend=backend)
    sharded = _sweep(setup, mesh=make_cell_model_mesh(4, 2), backend=backend)
    _assert_records_close(unsharded, sharded)


@needs_8_devices
def test_4x2_vs_8x1_equivalent(setup):
    """Sharding the model axis instead of more cells changes placement, not
    semantics: 4×2 matches 8×1 within the same reduction tolerance."""
    wide = _sweep(setup, mesh=make_cell_model_mesh(8, 1))
    deep = _sweep(setup, mesh=make_cell_model_mesh(4, 2))
    _assert_records_close(wide, deep)


@needs_8_devices
def test_repeat_4x2_sweep_zero_retraces_one_compile(setup):
    """Acceptance pin: repeat 2-D sweeps re-trace zero; the fused sweep
    compiled exactly one lattice program."""
    data, params0, ev = setup
    mesh = make_cell_model_mesh(4, 2)
    spec = LatticeSpec(policies=("pofl", "channel"), seeds=(0, 1), n_rounds=3)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)

    first = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )
    engine = cached_engine(
        _loss_fn, data, dataclasses.replace(cfg, policy=FUSED_POLICY),
        eval_fn=ev, mesh=mesh,
    )
    traces, compiles = engine.n_lattice_traces, engine.n_compiles
    assert compiles == 1  # ONE policy-fused program for the whole sweep

    second = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )
    assert engine.n_lattice_traces == traces  # ZERO retraces
    assert engine.n_compiles == compiles  # ZERO recompiles
    _assert_records_equal(first, second)


@needs_8_devices
def test_4x2_memory_stats_report_2d_shape(setup):
    """lattice_memory_stats() reflects the live 2-D engine: mesh_shape
    (4, 2) and a positive per-device HBM figure."""
    from repro.sim import lattice_memory_stats, reset_engine_cache

    reset_engine_cache()  # make the 4x2 engine the only live one
    _sweep(setup, mesh=make_cell_model_mesh(4, 2))
    stats = lattice_memory_stats()
    assert stats is not None
    assert tuple(stats["mesh_shape"]) == (4, 2)
    assert stats["per_device_hbm_bytes"] > 0
