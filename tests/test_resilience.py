"""Fault-tolerant lattice suite (ISSUE 10 tentpole pin).

Three layers:

  * checkpoint/resume — the HARD bit-identity contract: a chunked sweep
    interrupted at ANY checkpoint boundary and resumed produces records
    bitwise equal to the uninterrupted chunked run (same chunk executable,
    same carries, bytewise npz round-trip), including the fully-stateful
    churn × dirichlet_mixed × feddyn cell;
  * deterministic fault injection — ``REPRO_FAULT_NAN`` poisons exactly one
    cell/round as an input VALUE (unfaulted cells share the executable and
    stay bitwise unchanged; the ``on_nonfinite="skip"`` quarantine holds
    params and counts the round on the ``health`` subtree), and the
    default-off path (``on_nonfinite="propagate"``, no env) adds ZERO ops;
  * supervision — per-rank crash restart with backoff, liveness kill of a
    silent rank, restart-budget exhaustion, and (``@pytest.mark.distributed``)
    the full launcher topology recovering an injected ``REPRO_FAULT_KILL``
    with records bit-identical to the unfaulted run.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pofl import DeviceData, POFLConfig
from repro.sim.lattice import LatticeSpec
from repro.sim.resilience import (
    ENV_FAULT_KILL,
    ENV_FAULT_NAN,
    FAULT_EXIT_CODE,
    CheckpointConfig,
    fault_kill,
    fault_nan,
    fault_nan_rounds,
    latest_checkpoint,
    merge_shards,
    run_lattice_checkpointed,
    run_worker_shard,
    shard_bounds,
)

_FLAT_FIELDS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")


def _tiny_task():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 20, 4))
    y = jax.random.randint(key, (8, 20), 0, 3)
    data = DeviceData(features=x, labels=y)
    params0 = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}

    def loss_fn(p, fx, fy):
        logits = fx @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, fy[:, None], axis=1))

    return loss_fn, data, params0


def _assert_bitwise(a, b, fields=_FLAT_FIELDS):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )


# --------------------------------------------------------------------------
# fault env contract
# --------------------------------------------------------------------------


def test_fault_env_parsing(monkeypatch):
    monkeypatch.delenv(ENV_FAULT_KILL, raising=False)
    monkeypatch.delenv(ENV_FAULT_NAN, raising=False)
    assert fault_kill() is None and fault_nan() is None

    monkeypatch.setenv(ENV_FAULT_KILL, "1:5")
    monkeypatch.setenv(ENV_FAULT_NAN, "3:2")
    assert fault_kill() == (1, 5)
    assert fault_nan() == (3, 2)

    monkeypatch.setenv(ENV_FAULT_KILL, "nonsense")
    with pytest.raises(ValueError, match="REPRO_FAULT_KILL"):
        fault_kill()


def test_fault_nan_rounds_slicing(monkeypatch):
    monkeypatch.delenv(ENV_FAULT_NAN, raising=False)
    np.testing.assert_array_equal(fault_nan_rounds(0, 3), [-1, -1, -1])
    monkeypatch.setenv(ENV_FAULT_NAN, "5:7")
    np.testing.assert_array_equal(fault_nan_rounds(4, 8), [-1, 7, -1, -1])
    # the named cell lives in another worker's slice: nothing injected here
    np.testing.assert_array_equal(fault_nan_rounds(0, 4), [-1, -1, -1, -1])


def test_shard_bounds_tile_exactly():
    for n_cells, count in ((8, 2), (7, 3), (5, 5), (3, 2)):
        spans = [shard_bounds(n_cells, r, count) for r in range(count)]
        assert spans[0][0] == 0 and spans[-1][1] == n_cells
        for (_, hi), (lo, _) in zip(spans, spans[1:]):
            assert hi == lo
    with pytest.raises(ValueError):
        shard_bounds(8, 2, 2)


# --------------------------------------------------------------------------
# checkpoint/resume bit-identity (the tentpole contract)
# --------------------------------------------------------------------------


def test_resume_any_boundary_bit_identical(tmp_path):
    """Interrupt at EVERY checkpoint boundary; each resume must reproduce
    the uninterrupted chunked run bit for bit (same executable, same
    carries — n_rounds=7 with every=3 also exercises the padded short
    final chunk)."""
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0, 1), n_rounds=7, eval_every=3,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    kw = dict(base_cfg=cfg)

    full = run_lattice_checkpointed(
        loss_fn, data, params0, spec,
        checkpoint=CheckpointConfig(dir=str(tmp_path / "full"), every=3), **kw,
    )
    for boundary in (3, 6):
        d = str(tmp_path / f"stop{boundary}")
        ck = CheckpointConfig(dir=d, every=3)
        out = run_lattice_checkpointed(
            loss_fn, data, params0, spec, checkpoint=ck,
            _stop_after_round=boundary, **kw,
        )
        assert out is None  # the simulated crash fired
        assert latest_checkpoint(d)[0] == boundary
        resumed = run_lattice_checkpointed(
            loss_fn, data, params0, spec, checkpoint=ck, **kw,
        )
        _assert_bitwise(full, resumed)
        np.testing.assert_array_equal(full.eval_rounds, resumed.eval_rounds)


def test_resume_churn_dirichlet_feddyn_bit_identical(tmp_path):
    """The fully-stateful acceptance cell: churn channel scenario,
    dirichlet_mixed partition (true sizes in ``n_samples``), traced
    fedavg+feddyn axis — the resumed carry includes channel state AND
    ``AlgState.h`` and must still be bit-identical."""
    from repro.data.partition import partition_dirichlet_mixed
    from repro.data.synthetic import make_classification_dataset

    key = jax.random.PRNGKey(1)
    x, y = make_classification_dataset("mnist_like", 160, key, dim=8)
    data = partition_dirichlet_mixed(x, y, n_devices=8, seed=0)
    params0 = {"w": jnp.zeros((8, 10)), "b": jnp.zeros((10,))}

    def loss_fn(p, fx, fy):
        logits = fx @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, fy[:, None], axis=1))

    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0, 1), n_rounds=5, algorithms=("fedavg", "feddyn"),
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    kw = dict(base_cfg=cfg, scenario="churn")

    full = run_lattice_checkpointed(
        loss_fn, data, params0, spec,
        checkpoint=CheckpointConfig(dir=str(tmp_path / "full"), every=2), **kw,
    )
    ck = CheckpointConfig(dir=str(tmp_path / "stop"), every=2)
    assert run_lattice_checkpointed(
        loss_fn, data, params0, spec, checkpoint=ck,
        _stop_after_round=2, **kw,
    ) is None
    resumed = run_lattice_checkpointed(
        loss_fn, data, params0, spec, checkpoint=ck, **kw,
    )
    _assert_bitwise(full, resumed)


def test_resume_refuses_foreign_fingerprint(tmp_path):
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0,), n_rounds=4,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    ck = CheckpointConfig(dir=str(tmp_path), every=2)
    assert run_lattice_checkpointed(
        loss_fn, data, params0, spec, base_cfg=cfg, checkpoint=ck,
        _stop_after_round=2,
    ) is None
    other = POFLConfig(n_devices=8, n_scheduled=4)  # different sweep
    with pytest.raises(ValueError, match="different sweep"):
        run_lattice_checkpointed(
            loss_fn, data, params0, spec, base_cfg=other, checkpoint=ck,
        )


def test_checkpoint_pruning_keeps_newest(tmp_path):
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0,), n_rounds=6,
    )
    ck = CheckpointConfig(dir=str(tmp_path), every=2, keep=1)
    run_lattice_checkpointed(
        loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=8, n_scheduled=3), checkpoint=ck,
    )
    npzs = [n for n in os.listdir(tmp_path) if n.endswith(".npz")]
    metas = [n for n in os.listdir(tmp_path) if n.endswith(".meta.json")]
    assert npzs == ["ckpt-000006.npz"] and metas == ["ckpt-000006.meta.json"]


def test_checkpoint_roundtrip_full_carry(tmp_path):
    """The persisted carry — params, PRNG key, channel state, stateful
    AlgState (feddyn h / scaffold c), None-flattening optional subtrees —
    survives the npz round-trip bitwise, into a zeroed template of the same
    structure."""
    from repro.checkpoint import load_pytree, save_pytree
    from repro.sim.engine import cached_engine

    loss_fn, data, params0 = _tiny_task()
    for algorithm in ("feddyn", "scaffold"):
        cfg = POFLConfig(
            n_devices=8, n_scheduled=3, local_algorithm=algorithm,
        )
        eng = cached_engine(loss_fn, data, cfg)
        state = eng.init_lattice_states(params0, jnp.asarray([0, 1], jnp.int32))
        assert state.alg is not None  # the stateful carry is actually there
        path = str(tmp_path / f"carry-{algorithm}")
        save_pytree(path, {"state": state}, metadata={"algorithm": algorithm})
        template = jax.tree.map(jnp.zeros_like, state)
        back = load_pytree(path, {"state": template})["state"]
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jax.tree.structure(state) == jax.tree.structure(
            jax.tree.map(jnp.asarray, back)
        )


@pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs a multi-device cell mesh"
)
def test_checkpoint_roundtrip_sharded_carry(tmp_path):
    """Sharded leaves gather to host on save and re-place onto the
    template's shardings on load — byte-identical values, same shardings."""
    from repro.checkpoint import load_pytree, save_pytree
    from repro.sim.engine import cached_engine
    from repro.sim.lattice import make_cell_mesh

    loss_fn, data, params0 = _tiny_task()
    mesh = make_cell_mesh(len(jax.devices()))
    cfg = POFLConfig(n_devices=8, n_scheduled=3, local_algorithm="feddyn")
    eng = cached_engine(loss_fn, data, cfg, mesh=mesh)
    seeds = jnp.arange(len(jax.devices()), dtype=jnp.int32)
    state = eng.init_lattice_states(params0, seeds)
    path = str(tmp_path / "sharded-carry")
    save_pytree(path, {"state": state})
    back = load_pytree(path, {"state": state})["state"]
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if hasattr(a, "sharding"):
            assert a.sharding == b.sharding


# --------------------------------------------------------------------------
# NaN fault injection + in-trace quarantine
# --------------------------------------------------------------------------


def test_nan_quarantine_isolated_and_counted(monkeypatch):
    """Poisoning one flat cell's aggregate at one round (a) leaves every
    OTHER cell bitwise unchanged vs the unfaulted run of the same
    executable, (b) shows up exactly once on the health subtree, and
    (c) never propagates PAST the poisoned round in the faulted cell — the
    quarantine held the previous params, so every later round's records are
    finite again (the round-2 record itself honestly carries the NaN; the
    health flag is how consumers find it)."""
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0, 1), n_rounds=5,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3, on_nonfinite="skip")

    monkeypatch.delenv(ENV_FAULT_NAN, raising=False)
    clean = run_lattice_checkpointed(loss_fn, data, params0, spec, base_cfg=cfg)
    assert clean.health is not None
    assert float(np.sum(clean.health.nonfinite)) == 0.0

    monkeypatch.setenv(ENV_FAULT_NAN, "1:2")  # flat cell 1, round 2
    faulted = run_lattice_checkpointed(loss_fn, data, params0, spec, base_cfg=cfg)

    n_cells, T = spec.n_cells, spec.n_rounds
    health = np.asarray(faulted.health.nonfinite).reshape(n_cells, T)
    assert health.sum() == 1.0 and health[1, 2] == 1.0
    for f in _FLAT_FIELDS:
        a = np.asarray(getattr(clean, f)).reshape(n_cells, -1)
        b = np.asarray(getattr(faulted, f)).reshape(n_cells, -1)
        for cell in range(n_cells):
            if cell == 1:
                # the quarantine held params: rounds after the poisoned one
                # are finite again (only the flagged round may carry NaN)
                if b[cell].shape[-1] == T:
                    assert np.all(np.isfinite(np.delete(b[cell], 2))), f
            else:
                np.testing.assert_array_equal(a[cell], b[cell], err_msg=f)


def test_quarantine_holds_params_and_alg_state():
    """A quarantined round is 'a round that never happened' for the model:
    with every round poisoned, params never move (grad_norm of the frozen
    params repeats identically), while the PRNG chain still advances (the
    schedule keeps sampling)."""
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0,), n_rounds=4,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3, on_nonfinite="skip")
    os.environ[ENV_FAULT_NAN] = "0:0"
    try:
        r0 = run_lattice_checkpointed(loss_fn, data, params0, spec, base_cfg=cfg)
    finally:
        del os.environ[ENV_FAULT_NAN]
    health = np.asarray(r0.health.nonfinite).ravel()
    assert health[0] == 1.0 and health.sum() == 1.0
    # params were held through the poisoned round 0, so rounds 1+ compute
    # finite records from the original (frozen) params
    assert np.all(np.isfinite(np.asarray(r0.grad_norm).ravel()[1:]))


def test_on_nonfinite_validation():
    loss_fn, data, _ = _tiny_task()
    from repro.sim.engine import cached_engine

    with pytest.raises(ValueError, match="on_nonfinite"):
        cached_engine(
            loss_fn, data,
            POFLConfig(n_devices=8, n_scheduled=3, on_nonfinite="explode"),
        )


def test_default_off_zero_new_ops():
    """The default-off guarantee: with ``on_nonfinite="propagate"`` (and no
    fault input) the traced program contains NO finiteness machinery and the
    record's health subtree is None — the pre-PR program, bit for bit (the
    pinned-trajectory batteries in test_sim/test_fused_lattice hold this
    across the suite)."""
    from repro.sim.engine import cached_engine

    loss_fn, data, params0 = _tiny_task()

    def jaxpr_for(cfg):
        eng = cached_engine(loss_fn, data, cfg)
        state = eng.init(params0, 0)
        t_ints = jnp.arange(3, dtype=jnp.int32)
        do_eval = jnp.zeros(3, bool)
        return str(jax.make_jaxpr(
            lambda s: eng.scan_rounds(s, t_ints, do_eval)
        )(state))

    off = jaxpr_for(POFLConfig(n_devices=8, n_scheduled=3))
    on = jaxpr_for(POFLConfig(n_devices=8, n_scheduled=3, on_nonfinite="skip"))
    assert "is_finite" not in off
    assert "is_finite" in on

    eng = cached_engine(loss_fn, data, POFLConfig(n_devices=8, n_scheduled=3))
    state = eng.init(params0, 0)
    _, rec = jax.jit(
        lambda s: eng.scan_rounds(
            s, jnp.arange(2, dtype=jnp.int32), jnp.zeros(2, bool)
        )
    )(state)
    assert rec.health is None


# --------------------------------------------------------------------------
# shard workers + merge
# --------------------------------------------------------------------------


def test_shard_merge_matches_full_run(tmp_path):
    loss_fn, data, params0 = _tiny_task()
    spec = LatticeSpec(
        policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0, 1), n_rounds=4,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    # reference: the SAME chunk length as the workers, so the comparison is
    # within one program (cross-chunk-length comparisons are cross-program)
    full = run_lattice_checkpointed(
        loss_fn, data, params0, spec, base_cfg=cfg,
        checkpoint=CheckpointConfig(dir=str(tmp_path / "full"), every=2),
    )
    paths = []
    for rank in range(2):
        p = str(tmp_path / f"shard-r{rank}.npz")
        run_worker_shard(
            loss_fn, data, params0, spec, p, str(tmp_path / "ckpt"), 2,
            rank=rank, count=2, base_cfg=cfg,
        )
        paths.append(p)
    merged = merge_shards(spec, paths)
    _assert_bitwise(full, merged)

    with pytest.raises(ValueError, match="shards"):
        merge_shards(spec, paths[:1])


# --------------------------------------------------------------------------
# supervision (fast in-process: tiny non-jax worker scripts)
# --------------------------------------------------------------------------

_CRASH_THEN_SUCCEED = textwrap.dedent("""
    import os, sys
    rank = os.environ["REPRO_DIST_PROCESS_ID"]
    marker = os.path.join({d!r}, "attempted-" + rank)
    if not os.path.exists(marker):
        open(marker, "w").close()
        sys.exit({rc})
    print("rank", rank, "recovered")
""")


def _run_supervised(script, n_procs=2, **sup_kw):
    from repro.launch.distributed import SupervisorConfig, supervise_workers

    return supervise_workers(
        [sys.executable, "-c", script],
        n_procs=n_procs,
        devices_per_proc=1,
        timeout=60.0,
        supervisor=SupervisorConfig(
            backoff_base=0.05, poll_interval=0.05, **sup_kw
        ),
    )


def test_supervisor_restarts_crashed_rank(tmp_path):
    results = _run_supervised(
        _CRASH_THEN_SUCCEED.format(d=str(tmp_path), rc=7), max_restarts=2
    )
    assert [r.returncode for r in results] == [0, 0]
    # both ranks crashed once, were restarted, then recovered
    for r in results:
        assert "recovered" in r.output
        assert f"rank {r.process_id} crashed (rc=7); restart 1/2" in r.output


def test_supervisor_exhausts_restart_budget(tmp_path):
    always_crash = "import sys; sys.exit(3)"
    with pytest.raises(RuntimeError, match="supervised workers failed") as ei:
        _run_supervised(always_crash, max_restarts=1)
    assert "restart budget" in str(ei.value)
    assert "rc=3" in str(ei.value)  # the per-rank tails name the exit code


def test_supervisor_strips_fault_env_on_restart(tmp_path, monkeypatch):
    """Injected faults are one-shot: the env var is present on attempt 0 and
    stripped on the restart, so the restarted rank recovers instead of
    re-crashing forever."""
    monkeypatch.setenv(ENV_FAULT_KILL, "0:0")
    script = textwrap.dedent("""
        import os, sys
        sys.exit(113 if os.environ.get("REPRO_FAULT_KILL") else 0)
    """)
    results = _run_supervised(script, n_procs=1, max_restarts=1)
    assert results[0].returncode == 0
    assert "restart 1/1" in results[0].output


def test_supervisor_liveness_kills_silent_rank(tmp_path):
    """A rank that hangs without heartbeating is killed at the liveness
    timeout and restarted — the topology never waits for the absolute
    deadline."""
    hang_then_succeed = textwrap.dedent("""
        import os, sys, time
        marker = os.path.join({d!r}, "hung")
        if not os.path.exists(marker):
            open(marker, "w").close()
            time.sleep(600)
        print("recovered after hang")
    """).format(d=str(tmp_path))
    results = _run_supervised(
        hang_then_succeed, n_procs=1, max_restarts=1, liveness_timeout=1.5
    )
    assert results[0].returncode == 0
    assert "went silent" in results[0].output
    assert "recovered after hang" in results[0].output


def test_spawn_local_deadline_kill_accounting():
    """spawn_local's straggler bookkeeping: the rank killed at the deadline
    reports a signal rc and the kill note; the rank that exited cleanly
    keeps its real rc (never rewritten to -9)."""
    from repro.launch.distributed import spawn_local

    script = textwrap.dedent("""
        import os, time
        if os.environ["REPRO_DIST_PROCESS_ID"] == "0":
            raise SystemExit(0)
        time.sleep(600)
    """)
    results = spawn_local(
        [sys.executable, "-c", script], n_procs=2, devices_per_proc=1,
        timeout=2.0,
    )
    assert results[0].returncode == 0
    assert "killed at the" not in results[0].output
    assert results[1].returncode == -9
    assert "killed at the 2.0s deadline" in results[1].output


# --------------------------------------------------------------------------
# the full supervised topology under an injected kill (CI fault-injection job)
# --------------------------------------------------------------------------


@pytest.mark.distributed
def test_injected_kill_recovers_bit_identical(tmp_path, monkeypatch):
    """Acceptance: REPRO_FAULT_KILL=1:N kills rank 1 mid-sweep (after its
    round-2 checkpoint), the supervisor restarts it within the budget, it
    resumes from checkpoint, and the merged records are BIT-IDENTICAL to
    the unfaulted supervised run of the same workload."""
    from repro.launch.distributed import SupervisorConfig, run_resilient
    from repro.obs.sink import read_events

    obs = tmp_path / "obs"
    monkeypatch.delenv(ENV_FAULT_KILL, raising=False)
    clean = run_resilient(
        2, str(tmp_path / "clean"), n_rounds=4, checkpoint_every=2,
        timeout=600.0,
    )

    monkeypatch.setenv(ENV_FAULT_KILL, "1:2")
    monkeypatch.setenv("REPRO_OBS_DIR", str(obs))
    faulted = run_resilient(
        2, str(tmp_path / "fault"), n_rounds=4, checkpoint_every=2,
        timeout=600.0,
        supervisor=SupervisorConfig(max_restarts=2, backoff_base=0.1),
    )
    _assert_bitwise(clean, faulted)

    events = list(read_events(str(obs)))
    restarts = [e for e in events if e["name"] == "supervisor.restart"]
    assert len(restarts) == 1  # recovered in one restart, budget respected
    assert restarts[0]["rank"] == 1
    assert restarts[0]["rc"] == FAULT_EXIT_CODE
    kills = [e for e in events if e["name"] == "resilience.fault_kill"]
    assert len(kills) == 1 and kills[0]["process_index"] == 1
    # the restarted rank announced its resume from the checkpoint
    resumes = [e for e in events if e["name"] == "resilience.resume"]
    assert len(resumes) == 1 and resumes[0]["process_index"] == 1
    assert resumes[0]["t_next"] >= 2
