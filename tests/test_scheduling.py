"""Scheduling tests: Eq. 34/35 optimality, Lemma 2 unbiasedness (property-based,
including under dropout/churn availability with Dirichlet-sized shards),
Eq. 36/37 sampling, and the PO-FL-B Horvitz–Thompson variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import scheduling
from repro.core.channel import ChannelConfig
from repro.core.numerics import safe_div
from repro.data import dirichlet_sizes
from repro.sim import make_channel_process


def _inputs(key, n=12, dim=128):
    k1, k2, k3 = jax.random.split(key, 3)
    norms = jax.random.uniform(k1, (n,), minval=0.1, maxval=5.0)
    gvars = jax.random.uniform(k2, (n,), minval=0.01, maxval=1.0)
    h_abs = jax.random.uniform(k3, (n,), minval=1e-3, maxval=1.0)
    frac = jnp.full((n,), 1.0 / n)
    return norms, gvars, h_abs, frac


# ---------------------------------------------------------------- Eq. 34/35
def test_probs_sum_to_one_all_policies():
    norms, gvars, h_abs, frac = _inputs(jax.random.PRNGKey(0))
    for policy in scheduling.POLICIES:
        p = scheduling.scheduling_probs(
            policy, norms, gvars, h_abs, frac, 128, 0.1, 1.0, 1e-11
        )
        np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-6)
        assert bool(jnp.all(p > 0))


def test_pofl_probs_solve_p2_kkt():
    """Eq. 34 is the KKT point of the convex problem (P2): compare against a
    numerical minimizer over the simplex (projected gradient descent)."""
    norms, gvars, h_abs, frac = _inputs(jax.random.PRNGKey(1), n=6)
    dim, alpha, P, s2 = 256, 0.1, 1.0, 1e-4

    p_star = scheduling.scheduling_probs(
        "pofl", norms, gvars, h_abs, frac, dim, alpha, P, s2
    )

    v_g = jnp.sum(frac * gvars)

    def objective(p):
        com = jnp.sum((1 + alpha) * dim * s2 * v_g * frac**2 / (p * P * h_abs**2))
        var = jnp.sum((1 + 1 / alpha) * (1.0 / p - 1.0) * frac**2 * norms**2)
        return com + var

    # numerical optimum via mirror descent on the simplex
    p = jnp.full_like(p_star, 1.0 / p_star.shape[0])
    g_fn = jax.grad(objective)
    for _ in range(3000):
        p = p * jnp.exp(-0.05 * g_fn(p) / (jnp.abs(g_fn(p)).max() + 1e-12))
        p = p / p.sum()
    assert float(objective(p_star)) <= float(objective(p)) * (1 + 1e-4)
    np.testing.assert_allclose(p, p_star, rtol=5e-2)


def test_pofl_probability_tradeoffs():
    """Remark 1: worse channel => higher probability (communication term);
    larger gradient norm => higher probability (importance term)."""
    n = 4
    frac = jnp.full((n,), 0.25)
    gvars = jnp.full((n,), 0.5)
    # channel varies, norms equal -> p increasing as channel degrades
    norms = jnp.ones((n,))
    h_abs = jnp.array([1.0, 0.5, 0.25, 0.125])
    p = scheduling.scheduling_probs("pofl", norms, gvars, h_abs, frac, 1000, 0.1, 1.0, 1e-2)
    assert bool(jnp.all(jnp.diff(p) > 0))
    # norms vary, channels equal -> p increasing with importance
    norms = jnp.array([0.5, 1.0, 2.0, 4.0])
    h_abs = jnp.ones((n,))
    p = scheduling.scheduling_probs("pofl", norms, gvars, h_abs, frac, 1000, 0.1, 1.0, 1e-11)
    assert bool(jnp.all(jnp.diff(p) > 0))


# ------------------------------------------------- Eq. 36/37 and Lemma 2
def test_sample_without_replacement_no_duplicates():
    p = jnp.array([0.4, 0.3, 0.2, 0.05, 0.05])
    for seed in range(20):
        s = scheduling.sample_without_replacement(jax.random.PRNGKey(seed), p, 3)
        idx = np.asarray(s.indices)
        assert len(set(idx.tolist())) == 3
        assert float(jnp.sum(s.mask)) == 3.0


def test_single_device_unbiasedness_lemma2():
    """Lemma 2 (|S|=1): E[ρ_i g_i · 1{i∈S}] = Σ_j (m_j/M) g_j exactly."""
    n = 5
    p = jnp.array([0.35, 0.3, 0.2, 0.1, 0.05])
    frac = jnp.array([0.1, 0.15, 0.2, 0.25, 0.3])
    g = jax.random.normal(jax.random.PRNGKey(0), (n, 8))
    target = jnp.sum(frac[:, None] * g, axis=0)

    # exact expectation by enumeration over the single selected device
    est = jnp.zeros(8)
    for i in range(n):
        rho_i = frac[i] / p[i]
        est = est + p[i] * rho_i * g[i]
    np.testing.assert_allclose(est, target, rtol=1e-6)


def test_multi_device_eq37_empirical_bias():
    """Reproduction observation: the Eq. 37 sequential estimator is exactly
    unbiased only for |S| = 1; for |S| > 1 a small bias remains (documented in
    DESIGN.md). The PO-FL-B Bernoulli variant removes it (next test). Here we
    quantify Eq. 37's bias and assert it is bounded."""
    n, S = 5, 3
    p = jnp.array([0.35, 0.3, 0.2, 0.1, 0.05])
    frac = jnp.full((n,), 1.0 / n)
    g = jnp.eye(n)  # estimator of the mean basis vector

    def draw(key):
        s = scheduling.sample_without_replacement(key, p, S)
        rho = scheduling.aggregation_weights(s, p, frac, S)
        return jnp.sum((rho * s.mask)[:, None] * g, axis=0)

    keys = jax.random.split(jax.random.PRNGKey(1), 30000)
    est = jnp.mean(jax.vmap(draw)(keys), axis=0)
    target = frac  # Σ frac_i e_i
    rel_bias = float(jnp.linalg.norm(est - target) / jnp.linalg.norm(target))
    assert rel_bias < 0.35, f"Eq.37 bias blew up: {rel_bias}"


def test_bernoulli_variant_exactly_unbiased():
    """PO-FL-B: Horvitz–Thompson inclusion weights are exactly unbiased —
    verified by *enumeration* over all 2^N inclusion patterns."""
    n, S = 4, 2
    p = jnp.array([0.4, 0.3, 0.2, 0.1])
    frac = jnp.array([0.1, 0.2, 0.3, 0.4])
    pi = scheduling.bernoulli_inclusion_probs(p, S)
    np.testing.assert_allclose(float(jnp.sum(pi)), S, rtol=1e-5)
    rho = scheduling.bernoulli_weights(pi, frac)
    g = jax.random.normal(jax.random.PRNGKey(2), (n, 6))

    est = jnp.zeros(6)
    for bits in range(2**n):
        mask = jnp.array([(bits >> i) & 1 for i in range(n)], jnp.float32)
        prob = float(jnp.prod(jnp.where(mask > 0, pi, 1 - pi)))
        est = est + prob * jnp.sum((rho * mask)[:, None] * g, axis=0)
    target = jnp.sum(frac[:, None] * g, axis=0)
    np.testing.assert_allclose(est, target, rtol=1e-4, atol=1e-6)


# ------------------------------------------------------- property tests
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 16),
    s=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(1e-3, 100.0),
)
def test_property_probs_valid_and_sampler_consistent(n, s, seed, alpha):
    s = min(s, n)
    key = jax.random.PRNGKey(seed)
    norms, gvars, h_abs, frac = _inputs(key, n=n)
    p = scheduling.scheduling_probs("pofl", norms, gvars, h_abs, frac, 64, alpha, 1.0, 1e-8)
    assert abs(float(p.sum()) - 1.0) < 1e-5
    sched = scheduling.sample_without_replacement(key, p, s)
    assert float(sched.mask.sum()) == float(s)
    # step probs are valid probabilities
    assert bool(jnp.all(sched.step_probs > 0)) and bool(jnp.all(sched.step_probs <= 1 + 1e-4))
    # HT inclusion probs well-formed
    pi = scheduling.bernoulli_inclusion_probs(p, s)
    assert abs(float(pi.sum()) - s) < 1e-3
    assert bool(jnp.all(pi > 0)) and bool(jnp.all(pi <= 1.0))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 10), s=st.integers(1, 4))
def test_property_aggregation_unbiased_nonuniform_frac(seed, n, s):
    """Heterogeneous-shard acceptance: aggregation stays unbiased when
    m_i/M is non-uniform. Exact expectations, no Monte Carlo:

      * Eq. 37, |S|=1 — enumerate the drawn device: Σ_i p_i·(m_i/(M p_i))·g_i
        must equal Σ_i (m_i/M)·g_i for ANY positive data_frac.
      * Horvitz–Thompson (PO-FL-B), any |S| — E[mask_i] = π_i, so the
        analytic mean Σ_i π_i·ρ_i·g_i must equal the same target.
    """
    s = min(s, n)
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    probs = jax.random.dirichlet(k1, jnp.full((n,), 1.5))
    probs = probs / probs.sum()
    frac = jax.random.dirichlet(k2, jnp.full((n,), 0.7))  # non-uniform m_i/M
    frac = frac / frac.sum()
    g = jax.random.normal(k3, (n, 5))
    target = np.asarray(jnp.sum(frac[:, None] * g, axis=0))

    # Eq. 37 with |S| = 1: exact enumeration over the single draw
    est = np.zeros(5)
    for i in range(n):
        sched = scheduling.Schedule(
            indices=jnp.array([i], jnp.int32),
            step_probs=probs[i][None],
            mask=jnp.zeros(n).at[i].set(1.0),
        )
        rho = scheduling.aggregation_weights(sched, probs, frac, 1)
        est += float(probs[i]) * np.asarray(
            jnp.sum((rho * sched.mask)[:, None] * g, axis=0)
        )
    np.testing.assert_allclose(est, target, rtol=1e-4, atol=1e-5)

    # Horvitz–Thompson: analytically exact for any |S|
    pi = scheduling.bernoulli_inclusion_probs(probs, s)
    rho = scheduling.bernoulli_weights(pi, frac)
    est_ht = np.asarray(jnp.sum((pi * rho)[:, None] * g, axis=0))
    np.testing.assert_allclose(est_ht, target, rtol=1e-3, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(4, 12),
    s=st.integers(1, 4),
    scenario=st.sampled_from(["dropout", "churn"]),
)
def test_property_unbiased_and_finite_under_availability(seed, n, s, scenario):
    """Extends ``test_property_aggregation_unbiased_nonuniform_frac`` beyond
    static availability: devices drop (i.i.d. ``dropout``) or churn (sticky
    Markov ``churn``) and shards are Dirichlet-*sized* (non-uniform m_i/M).
    Conditional on the realized availability mask, aggregation must stay
    unbiased for the available-population target Σ_{i avail} (m_i/M)·g_i —
    exact expectations, no Monte Carlo — and every weight must stay finite
    and exactly zero off the available set (a prob-0 device that slipped a
    positive weight would chase offline devices, the artifact the dropout
    scenario exists to rule out).
    """
    key = jax.random.PRNGKey(seed)
    k_ch, k_roll, k_g, k_q = jax.random.split(key, 4)

    # dirichlet_sized shard fractions (Σ m_i = 40n, every m_i ≥ 1)
    sizes = dirichlet_sizes(40 * n, n, beta=0.4, seed=seed % 100000)
    frac = jnp.asarray(sizes / sizes.sum(), jnp.float32)

    params = (
        {"p_drop": 0.4} if scenario == "dropout"
        else {"p_depart": 0.3, "p_arrive": 0.3}
    )
    proc = make_channel_process(scenario, ChannelConfig(n_devices=n), **params)
    state = proc.init(k_ch)
    for k in jax.random.split(k_roll, 4):  # roll so the churn chain trends
        state, h, avail = proc.step(state, k)

    # the exact masking scheduling_stage applies for can_drop scenarios
    norms = jnp.abs(jax.random.normal(k_q, (n,))) + 0.1
    probs = scheduling.scheduling_probs(
        "pofl", norms, jnp.ones(n), jnp.abs(h), frac, 64, 0.1, 1.0, 1e-9
    )
    masked = probs * avail
    probs_a = safe_div(masked, jnp.sum(masked))

    g = jax.random.normal(k_g, (n, 5))
    target = np.asarray(jnp.sum((avail * frac)[:, None] * g, axis=0))

    if int(avail.sum()) == 0:
        # an all-offline round schedules nothing and weighs nothing
        np.testing.assert_array_equal(np.asarray(probs_a), 0.0)
        return

    # Eq. 37 with |S| = 1: exact enumeration over the (available) draw
    est = np.zeros(5)
    for i in range(n):
        if float(probs_a[i]) == 0.0:
            continue  # unavailable → never drafted (sampler masks prob 0)
        sched = scheduling.Schedule(
            indices=jnp.array([i], jnp.int32),
            step_probs=probs_a[i][None],
            mask=jnp.zeros(n).at[i].set(1.0),
        )
        rho = scheduling.aggregation_weights(sched, probs_a, frac, 1)
        assert bool(jnp.isfinite(rho).all())
        np.testing.assert_array_equal(np.asarray(rho) * (1.0 - np.asarray(avail)), 0.0)
        est += float(probs_a[i]) * np.asarray(
            jnp.sum((rho * sched.mask)[:, None] * g, axis=0)
        )
    np.testing.assert_allclose(est, target, rtol=1e-4, atol=1e-5)

    # Horvitz–Thompson (PO-FL-B): E[mask_i] = π_i, analytic mean over the
    # available set (off-availability π floors at EPS but is never drawn)
    pi = scheduling.bernoulli_inclusion_probs(probs_a, s)
    rho_ht = scheduling.bernoulli_weights(pi, frac)
    assert bool(jnp.isfinite(rho_ht).all())
    est_ht = np.asarray(jnp.sum((avail * pi * rho_ht)[:, None] * g, axis=0))
    np.testing.assert_allclose(est_ht, target, rtol=1e-3, atol=1e-5)


@settings(max_examples=60, deadline=None)
@given(
    policy=st.sampled_from(scheduling.POLICIES),
    n=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
    h_regime=st.sampled_from(["normal", "faded", "underflow", "zero"]),
    zero_norms=st.booleans(),
    onehot_frac=st.booleans(),
    alpha=st.floats(1e-3, 10.0),
    noise_power=st.sampled_from([0.0, 1e-11, 1e-2]),
)
def test_property_probs_distribution_under_extremes(
    policy, n, seed, h_regime, zero_norms, onehot_frac, alpha, noise_power
):
    """EVERY policy must emit a probability distribution no matter how
    degenerate the round looks: deep fades down to |h| = 0 exactly (whose
    float32 square underflows — the case the ``pofl_q`` denominator guard
    exists for), all-zero uploaded gradient norms, one-hot ``data_frac``
    (one device owns the whole dataset), σ_z² = 0, extreme α. Outputs must
    be finite, non-negative, and sum to 1 — a NaN here would silently poison
    every downstream Eq. 36/37 draw of a lattice cell."""
    key = jax.random.PRNGKey(seed)
    k_n, k_v, k_h = jax.random.split(key, 3)
    norms = (
        jnp.zeros((n,))
        if zero_norms
        else jax.random.uniform(k_n, (n,), minval=0.1, maxval=5.0)
    )
    gvars = jax.random.uniform(k_v, (n,), minval=0.0, maxval=1.0)
    h_scale = {"normal": 1.0, "faded": 1e-12, "underflow": 1e-25, "zero": 0.0}
    h_abs = jax.random.uniform(k_h, (n,), minval=0.0, maxval=1.0) * h_scale[h_regime]
    frac = (
        jnp.zeros((n,)).at[seed % n].set(1.0)
        if onehot_frac
        else jnp.full((n,), 1.0 / n)
    )
    p = scheduling.scheduling_probs(
        policy, norms, gvars, h_abs, frac, 128, alpha, 1.0, noise_power
    )
    assert bool(jnp.isfinite(p).all()), p
    assert bool((p >= 0).all()), p
    np.testing.assert_allclose(float(p.sum()), 1.0, rtol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 20),
    s=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    n_zero=st.integers(0, 5),
)
def test_property_sampler_invariants_and_eq36_renormalization(n, s, seed, n_zero):
    """Invariants of the Eq. 36 draw + the PO-FL-B inclusion probs:

      * no device is ever drawn twice, the mask is exactly the drawn set,
        and zero-probability devices are never drafted;
      * REPLAYING the draw in float64 shows Eq. 36's renormalization keeps
        every per-step live distribution a distribution (the not-yet-drawn
        masses q_i = p_i/(1 − Σ_{j<k} p_{Y_j}) sum to 1, each in (0, 1]);
        replaying in float32 *kernel order* pins the recorded ``step_probs``
        to the exact arithmetic the scan performed — near-exhausted mass
        makes 1−cum catastrophically cancel in float32, so the recorded
        value may exceed the float64 mass and only the float32 replay is the
        honest equality;
      * Σπ_i = n_scheduled for the Bernoulli inclusion probabilities, with
        every π_i in (0, 1].
    """
    s = min(s, n)
    n_zero = min(n_zero, n - s)  # keep at least s selectable devices
    key = jax.random.PRNGKey(seed)
    k_p, k_draw = jax.random.split(key)
    p = jax.random.dirichlet(k_p, jnp.full((n,), 1.2))
    p = p.at[:n_zero].set(0.0)  # offline devices (exchangeable draw)
    p = p / p.sum()

    sched = scheduling.sample_without_replacement(k_draw, p, s)
    idx = np.asarray(sched.indices)
    step_probs = np.asarray(sched.step_probs)
    mask = np.asarray(sched.mask)

    # enough selectable mass → every draw is real, and none repeats
    assert (idx >= 0).all(), idx
    assert len(set(idx.tolist())) == s, idx
    assert float(mask.sum()) == float(s)
    assert set(np.flatnonzero(mask).tolist()) == set(idx.tolist())
    p_np = np.asarray(p, np.float64)
    assert (p_np[idx] > 0).all(), "a zero-probability device was drafted"

    # replay the sequential draw: float64 for the mathematical invariant
    # (over the EXACTLY-normalized distribution — float32 p sums to 1 only
    # to ~n·eps, which tiny remaining mass would amplify), float32 in
    # kernel order for the recorded values
    p32 = np.asarray(p, np.float32)
    p_np = p_np / p_np.sum()
    cum64, cum32 = 0.0, np.float32(0.0)
    drawn: set[int] = set()
    for k in range(s):
        live = np.array([p_np[i] if i not in drawn else 0.0 for i in range(n)])
        q = live / (1.0 - cum64)
        np.testing.assert_allclose(q.sum(), 1.0, rtol=1e-9)
        assert 0.0 < q[idx[k]] <= 1.0 + 1e-12  # the true Eq. 36 mass
        q32 = p32[idx[k]] / max(np.float32(1.0) - cum32, np.float32(1e-30))
        assert step_probs[k] > 0.0
        np.testing.assert_allclose(step_probs[k], q32, rtol=1e-5)
        drawn.add(int(idx[k]))
        cum64 += p_np[idx[k]]
        cum32 = np.float32(cum32 + p32[idx[k]])

    # Σπ = n_scheduled (bisection target), π a valid inclusion-prob vector
    pi = np.asarray(scheduling.bernoulli_inclusion_probs(p, s))
    assert np.isfinite(pi).all()
    assert (pi > 0).all() and (pi <= 1.0).all()
    np.testing.assert_allclose(pi.sum(), s, rtol=1e-3)


@settings(max_examples=60, deadline=None)
@given(
    policy=st.sampled_from(scheduling.POLICIES),
    n=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
    h_regime=st.sampled_from(["normal", "faded", "underflow", "zero"]),
    zero_norms=st.booleans(),
    onehot_frac=st.booleans(),
    alpha=st.floats(1e-3, 10.0),
    noise_power=st.sampled_from([0.0, 1e-11, 1e-2]),
)
def test_property_probs_by_id_tracks_string_dispatch(
    policy, n, seed, h_regime, zero_norms, onehot_frac, alpha, noise_power
):
    """ISSUE 5 tentpole pin: for EVERY policy id, the traced ``lax.switch``
    dispatch (``scheduling_probs_by_id``) computes the string dispatch's
    arithmetic. The branch table is op-for-op the string version, but XLA
    compiles HLO-conditional branch computations separately from the main
    computation, so internal reductions (``v_g_tilde``, the Σq normalizer)
    may round differently by ≤1 ULP — measured, deterministic, and
    identical in kind to the PR-4 cross-program ``e_var`` carve-out. The
    pin is therefore: ≤1-ULP agreement with the string dispatch (rtol 3e-7)
    in every form (direct and the vmapped all-branches-and-select form the
    fused lattice compiles), plus BITWISE lane determinism of the vmapped
    form. The end-to-end bitwise contract lives where it is achievable and
    load-bearing: the fused lattice vs its per-policy fallback (both
    switch programs) in tests/test_fused_lattice.py. Inputs include the
    PR-4 extremes: |h| → 0 exactly (float32 ``h²`` underflow), all-zero
    norms, one-hot data_frac, σ_z² = 0."""
    key = jax.random.PRNGKey(seed)
    k_n, k_v, k_h = jax.random.split(key, 3)
    norms = (
        jnp.zeros((n,))
        if zero_norms
        else jax.random.uniform(k_n, (n,), minval=0.1, maxval=5.0)
    )
    gvars = jax.random.uniform(k_v, (n,), minval=0.0, maxval=1.0)
    h_scale = {"normal": 1.0, "faded": 1e-12, "underflow": 1e-25, "zero": 0.0}
    h_abs = jax.random.uniform(k_h, (n,), minval=0.0, maxval=1.0) * h_scale[h_regime]
    frac = (
        jnp.zeros((n,)).at[seed % n].set(1.0)
        if onehot_frac
        else jnp.full((n,), 1.0 / n)
    )
    pid = scheduling.policy_id(policy)
    assert scheduling.POLICIES[pid] == policy

    def both(i, al, no):
        return (
            scheduling.scheduling_probs(
                policy, norms, gvars, h_abs, frac, 128, al, 1.0, no
            ),
            scheduling.scheduling_probs_by_id(
                i, norms, gvars, h_abs, frac, 128, al, 1.0, no
            ),
        )

    a32, s32 = jnp.float32(alpha), jnp.float32(noise_power)
    want, direct = jax.jit(both)(jnp.int32(pid), a32, s32)
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(direct), rtol=3e-7, atol=1e-10
    )
    assert np.isfinite(np.asarray(direct)).all()
    assert (np.asarray(direct) >= 0).all()
    np.testing.assert_allclose(float(np.asarray(direct).sum()), 1.0, rtol=1e-4)

    batched = jax.jit(jax.vmap(
        lambda i: scheduling.scheduling_probs_by_id(
            i, norms, gvars, h_abs, frac, 128, a32, 1.0, s32
        )
    ))(jnp.full((2,), pid, jnp.int32))
    np.testing.assert_array_equal(np.asarray(batched[0]), np.asarray(batched[1]))
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(batched[0]), rtol=3e-7, atol=1e-10
    )


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 20),
    s=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    n_zero=st.integers(0, 5),
)
def test_property_topk_sampler_invariants(n, s, seed, n_zero):
    """The Gumbel top-k fast path satisfies the sequential sampler's
    invariants: no device drawn twice, the mask is exactly the drawn set,
    zero-probability devices are never drafted, the recorded ``step_probs``
    are the Eq. 36 renormalized masses of the ordered draw (float64 replay),
    and Σπ_i = n_scheduled for the Bernoulli inclusion probabilities."""
    s = min(s, n)
    n_zero = min(n_zero, n - s)  # keep at least s selectable devices
    key = jax.random.PRNGKey(seed)
    k_p, k_draw = jax.random.split(key)
    p = jax.random.dirichlet(k_p, jnp.full((n,), 1.2))
    p = p.at[:n_zero].set(0.0)
    p = p / p.sum()

    sched = scheduling.sample_without_replacement(k_draw, p, s, method="topk")
    idx = np.asarray(sched.indices)
    step_probs = np.asarray(sched.step_probs)
    mask = np.asarray(sched.mask)

    assert (idx >= 0).all(), idx
    assert len(set(idx.tolist())) == s, idx
    assert float(mask.sum()) == float(s)
    assert set(np.flatnonzero(mask).tolist()) == set(idx.tolist())
    p_np = np.asarray(p, np.float64)
    assert (p_np[idx] > 0).all(), "a zero-probability device was drafted"

    # float64 replay of Eq. 36 over the ordered draw: the reconstructed
    # step_probs must be the renormalized live masses (float32-computed, so
    # compared at float32 tolerance, not bitwise)
    cum = 0.0
    for k in range(s):
        q = p_np[idx[k]] / (1.0 - cum)
        assert step_probs[k] > 0.0
        np.testing.assert_allclose(step_probs[k], q, rtol=1e-4)
        cum += p_np[idx[k]]

    pi = np.asarray(scheduling.bernoulli_inclusion_probs(p, s))
    assert np.isfinite(pi).all()
    assert (pi > 0).all() and (pi <= 1.0).all()
    np.testing.assert_allclose(pi.sum(), s, rtol=1e-3)


def test_topk_first_draw_chi_square_matches_sequential():
    """Distributional identity of the Gumbel top-k draw: the FIRST draw of
    ``method="topk"`` is a plain p-categorical, so its frequencies over many
    draws must pass a chi-square test against expected counts — and against
    ``method="sequential"``'s observed counts (two-sample). Thresholds are
    the χ² df=n−1 ≈0.999 quantiles; the seeds are fixed, so this is a
    deterministic regression test, not a flaky monte-carlo one."""
    p = jnp.array([0.3, 0.25, 0.2, 0.1, 0.1, 0.05])
    n, s, n_draws = p.shape[0], 3, 4000
    keys = jax.random.split(jax.random.PRNGKey(123), n_draws)

    def first(method):
        draw = jax.vmap(
            lambda k: scheduling.sample_without_replacement(
                k, p, s, method=method
            ).indices[0]
        )(keys if method == "topk" else jax.random.split(jax.random.PRNGKey(7), n_draws))
        return np.bincount(np.asarray(draw), minlength=n)

    obs_topk = first("topk")
    obs_seq = first("sequential")
    expected = np.asarray(p, np.float64) * n_draws
    chi2_threshold = 20.5  # χ²_{5, 0.999}
    for obs in (obs_topk, obs_seq):
        chi2 = float(np.sum((obs - expected) ** 2 / expected))
        assert chi2 < chi2_threshold, (obs, expected, chi2)
    # two-sample chi-square: topk vs sequential observed counts
    tot = obs_topk + obs_seq
    chi2_2s = float(np.sum((obs_topk - obs_seq) ** 2 / np.maximum(tot, 1)))
    assert chi2_2s < 2 * chi2_threshold, (obs_topk, obs_seq, chi2_2s)

    # later draws still cover the support without replacement
    sched = scheduling.sample_without_replacement(keys[0], p, n, method="topk")
    assert sorted(np.asarray(sched.indices).tolist()) == list(range(n))


def test_topk_clamps_when_selectable_mass_exhausted():
    """Fewer selectable devices than n_scheduled → sentinel no-op draws,
    exactly like the sequential path's clamp contract."""
    p = jnp.array([0.6, 0.4, 0.0, 0.0])
    sched = scheduling.sample_without_replacement(
        jax.random.PRNGKey(0), p, 3, method="topk"
    )
    idx = np.asarray(sched.indices)
    assert set(idx[:2].tolist()) == {0, 1}
    assert idx[2] == -1
    assert np.asarray(sched.step_probs)[2] == np.inf
    assert float(np.asarray(sched.mask).sum()) == 2.0
    # n_scheduled beyond the device count clamps too (top_k caps at n; the
    # sequential path's contract), instead of a trace-time top_k error
    over = scheduling.sample_without_replacement(
        jax.random.PRNGKey(1), jnp.array([0.7, 0.3]), 3, method="topk"
    )
    idx = np.asarray(over.indices)
    assert set(idx[:2].tolist()) == {0, 1} and idx[2] == -1
    assert float(np.asarray(over.mask).sum()) == 2.0
    with pytest.raises(ValueError, match="unknown sampling method"):
        scheduling.sample_without_replacement(jax.random.PRNGKey(0), p, 2, method="nope")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_eq37_weights_reduce_to_eq16_for_single(seed):
    key = jax.random.PRNGKey(seed)
    norms, gvars, h_abs, frac = _inputs(key, n=8)
    p = scheduling.scheduling_probs("pofl", norms, gvars, h_abs, frac, 64, 0.1, 1.0, 1e-9)
    sched = scheduling.sample_without_replacement(key, p, 1)
    rho = scheduling.aggregation_weights(sched, p, frac, 1)
    i = int(sched.indices[0])
    np.testing.assert_allclose(float(rho[i]), float(frac[i] / p[i]), rtol=1e-5)
