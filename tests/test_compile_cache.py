"""Persistent compilation cache (ISSUE 5): the REPRO_COMPILE_CACHE contract.

In-process unit tests for the enable/no-op/counter plumbing, plus a
subprocess pair proving compiles actually survive process death: a cold
process populates the cache directory, a second fresh process compiles the
same program and must log persistent-cache HITS (the same assertion CI's
warm pytest re-run makes via the conftest guard).
"""
from __future__ import annotations

import os
import subprocess
import sys

from repro.sim import compile_cache

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))

_PROBE = """
import jax, jax.numpy as jnp
from repro.sim.compile_cache import enable_compile_cache, persistent_cache_counters
assert enable_compile_cache() is not None
f = jax.jit(lambda x: jnp.sin(x) @ jnp.cos(x).T)
f(jnp.ones((32, 32))).block_until_ready()
print("HITS", persistent_cache_counters()["hits"])
"""


def test_enable_is_noop_without_contract(monkeypatch):
    monkeypatch.delenv(compile_cache.ENV_CACHE_DIR, raising=False)
    assert compile_cache.enable_compile_cache() is None
    assert compile_cache.cache_dir_entries() == 0


def test_cache_dir_entries_counts_payloads(tmp_path):
    (tmp_path / "a-cache").write_bytes(b"x")
    (tmp_path / "a-atime").write_bytes(b"x")  # LRU sidecar, not a payload
    (tmp_path / "b-cache").write_bytes(b"x")
    assert compile_cache.cache_dir_entries(str(tmp_path)) == 2
    assert compile_cache.cache_dir_entries(str(tmp_path / "missing")) == 0


def test_persistent_cache_hits_across_processes(tmp_path):
    """Cold process populates REPRO_COMPILE_CACHE; a FRESH process compiling
    the same program must be served from it (hits > 0) — in-memory jit
    caches cannot explain that, only the persistent layer can."""
    env = dict(
        os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
        REPRO_COMPILE_CACHE=str(tmp_path),
    )

    def probe() -> int:
        out = subprocess.run(
            [sys.executable, "-c", _PROBE], env=env,
            capture_output=True, text=True, timeout=120,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        return int(out.stdout.split("HITS")[1].strip())

    cold_hits = probe()
    assert compile_cache.cache_dir_entries(str(tmp_path)) > 0
    warm_hits = probe()
    assert cold_hits == 0
    assert warm_hits > 0
