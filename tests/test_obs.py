"""repro.obs flight-recorder suite (ISSUE 6 tentpole pin).

Layers under test:

  * registry/span/sink host-side plumbing — counter lifecycle, prefix reset
    scoping (the ``compile_cache.`` namespace must survive every reset the
    test fixtures perform), JSONL event stamping from the ``REPRO_DIST_*``
    contract without touching the jax backend;
  * the engine integration — ``engine_cache_stats`` /
    ``persistent_cache_counters`` as thin registry shims, lattice spans and
    ``lattice``-kind events, the warm-retrace report gate;
  * in-trace diagnostics — ``ObsConfig(diagnostics=True)`` returns the
    :class:`~repro.core.metrics.RoundDiagnostics` taps with UNCHANGED base
    records (OFF is bit-identical to the pre-obs program by construction —
    same trace; ON vs OFF is a cross-program comparison, so the base-record
    check is tight allclose, per the documented ≤1-ULP wobble), and a repeat
    diagnostics sweep re-traces zero times (the second engine-cache key);
  * the bench history satellite — ``benchmarks.run.append_history`` appends
    SHA+timestamp-stamped JSONL that ``benchmarks.report`` renders;
  * the ``@pytest.mark.distributed`` harness — a 2-process launcher run
    under one shared ``REPRO_OBS_DIR`` writes one event file per worker with
    consistent rank stamps and matching span totals.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.obs import (
    ObsConfig,
    close_sink,
    counter,
    counter_add,
    emit,
    event_files,
    gauge,
    metric_value,
    metrics_snapshot,
    process_coords,
    read_events,
    reset_metrics,
    span,
    span_totals,
)
from repro.obs.report import collect, gate_warm_lattice, render
from repro.obs.report import main as report_main

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


# --------------------------------------------------------------------------
# registry + spans + sink
# --------------------------------------------------------------------------


def test_counter_and_gauge_basics():
    assert metric_value("t.c") == 0
    assert counter_add("t.c") == 1
    assert counter_add("t.c", 2.5) == 3.5
    c = counter("t.c")
    c.add(1)
    assert c.value == 4.5
    g = gauge("t.g")
    g.set(7)
    g.set(3)
    assert g.value == 3
    snap = metrics_snapshot("t.")
    assert snap == {"t.c": 4.5, "t.g": 3}


def test_reset_metrics_is_prefix_scoped():
    counter_add("ns1.a")
    counter_add("ns2.b")
    reset_metrics("ns1.")
    assert metric_value("ns1.a") == 0
    assert metric_value("ns2.b") == 1
    reset_metrics("ns2.")


def test_span_records_registry_totals_and_propagates_exceptions():
    with span("t.work") as s:
        pass
    assert s.seconds is not None and s.seconds >= 0
    with pytest.raises(ValueError, match="boom"):
        with span("t.work"):
            raise ValueError("boom")
    totals = span_totals("t.work")
    assert totals["count"] == 2
    assert totals["seconds"] >= 0

    @span("t.deco")
    def f(x):
        return x + 1

    assert f(1) == 2
    assert span_totals("t.deco")["count"] == 1


def test_sink_inactive_without_env(monkeypatch):
    monkeypatch.delenv("REPRO_OBS_DIR", raising=False)
    ev = emit("counter", "t.quiet", delta=1, total=1)
    # the event dict is still assembled (registry callers rely on it) but
    # nothing is written anywhere
    assert ev["kind"] == "counter" and ev["name"] == "t.quiet"


def test_sink_writes_process_stamped_jsonl(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    # the rank stamp comes from the REPRO_DIST_* env contract, NOT from the
    # jax backend (the sink must stay importable/usable pre-init)
    monkeypatch.setenv("REPRO_DIST_PROCESS_ID", "1")
    monkeypatch.setenv("REPRO_DIST_NUM_PROCESSES", "2")
    assert process_coords() == (1, 2)
    with span("t.stamped", tag="x"):
        pass
    counter_add("t.stamped.extra")
    close_sink()
    files = event_files(str(tmp_path))
    assert len(files) == 1
    assert os.path.basename(files[0]).startswith("events-p001of002-")
    events = list(read_events(str(tmp_path)))
    assert {e["kind"] for e in events} == {"span", "counter"}
    for e in events:
        assert e["process_index"] == 1
        assert e["process_count"] == 2
        assert e["pid"] == os.getpid()
    (sp,) = [e for e in events if e["kind"] == "span"]
    assert sp["name"] == "t.stamped" and sp["tag"] == "x"


def test_read_events_skips_torn_lines(tmp_path):
    p = tmp_path / "events-p000of001-1.jsonl"
    p.write_text('{"kind": "counter", "name": "ok"}\n{"kind": "half\n\n')
    events = list(read_events(str(tmp_path)))
    assert len(events) == 1 and events[0]["name"] == "ok"


def test_sink_survives_killed_writer(tmp_path):
    """The resilience contract: a writer that dies hard (``os._exit``, as
    the ``REPRO_FAULT_KILL`` injection does — no atexit, no flush-on-close)
    loses at most the torn trailing line. Every event emitted before the
    kill must be durable on disk, and ``read_events`` must yield exactly
    those events past the tear."""
    script = (
        "import os\n"
        "os.environ['REPRO_OBS_DIR'] = r'%s'\n"
        "from repro.obs.sink import _handle, emit, obs_dir\n"
        "for i in range(3):\n"
        "    emit('heartbeat', 'killed.writer', i=i)\n"
        "h = _handle(obs_dir())\n"
        "h.write('{\"kind\": \"torn mid-li')\n"  # no newline: a torn write
        "os._exit(137)\n"
    ) % str(tmp_path)
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=120,
    )
    assert proc.returncode == 137, proc.stderr[-2000:]
    events = [e for e in read_events(str(tmp_path)) if e["name"] == "killed.writer"]
    assert [e["i"] for e in events] == [0, 1, 2]


# --------------------------------------------------------------------------
# engine integration: shims, lifecycle, diagnostics
# --------------------------------------------------------------------------


def _tiny_task():
    import jax
    import jax.numpy as jnp

    from repro.core.pofl import DeviceData

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 20, 4))
    y = jax.random.randint(key, (8, 20), 0, 3)
    data = DeviceData(features=x, labels=y)
    params0 = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}

    def loss_fn(p, fx, fy):
        logits = fx @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, fy[:, None], axis=1))

    return loss_fn, data, params0


def _tiny_spec(n_rounds=3):
    from repro.sim.lattice import LatticeSpec

    return LatticeSpec(
        policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
        seeds=(0, 1), n_rounds=n_rounds,
    )


def test_engine_cache_stats_is_registry_shim():
    from repro.core.pofl import POFLConfig
    from repro.sim.engine import cached_engine, engine_cache_stats

    loss_fn, data, _ = _tiny_task()
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    assert engine_cache_stats() == {"hits": 0, "misses": 0, "size": 0}
    e1 = cached_engine(loss_fn, data, cfg)
    e2 = cached_engine(loss_fn, data, cfg)
    assert e1 is e2
    assert engine_cache_stats() == {"hits": 1, "misses": 1, "size": 1}
    assert metric_value("engine_cache.hits") == 1
    assert metric_value("engine_cache.misses") == 1


def test_counter_lifecycle_reset_scoping():
    """reset_engine_cache zeroes exactly the engine_cache. namespace; the
    process-lifetime compile_cache. counters survive every reset a test (or
    the autouse fixture) performs — the CI EXPECT_HITS session guard depends
    on that."""
    from repro.sim.compile_cache import persistent_cache_counters
    from repro.sim.engine import engine_cache_stats, reset_engine_cache

    before = persistent_cache_counters()
    counter_add("engine_cache.hits", 5)
    counter_add("span.fake.count", 2)
    reset_engine_cache()
    assert engine_cache_stats() == {"hits": 0, "misses": 0, "size": 0}
    assert metric_value("span.fake.count") == 2  # other namespaces untouched
    assert persistent_cache_counters() == before
    reset_metrics("span.")


def test_obs_config_is_second_engine_cache_key():
    from repro.core.pofl import POFLConfig
    from repro.sim.engine import cached_engine, engine_cache_stats

    loss_fn, data, _ = _tiny_task()
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    plain = cached_engine(loss_fn, data, cfg)
    diag = cached_engine(loss_fn, data, cfg, obs=ObsConfig(diagnostics=True))
    assert plain is not diag
    assert diag.obs.diagnostics
    # and the diagnostics engine is itself cached
    assert cached_engine(
        loss_fn, data, cfg, obs=ObsConfig(diagnostics=True)
    ) is diag
    assert engine_cache_stats()["misses"] == 2


def test_diagnostics_off_is_default_and_diag_is_none():
    from repro.sim.lattice import run_lattice
    from repro.core.pofl import POFLConfig

    loss_fn, data, params0 = _tiny_task()
    recs = run_lattice(
        loss_fn, data, params0, _tiny_spec(),
        base_cfg=POFLConfig(n_devices=8, n_scheduled=3),
    )
    assert recs.diag is None


def test_diagnostics_taps_values_and_unchanged_base_records():
    from repro.core.metrics import RoundDiagnostics
    from repro.core.pofl import POFLConfig
    from repro.sim.lattice import run_lattice

    loss_fn, data, params0 = _tiny_task()
    spec = _tiny_spec()
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    off = run_lattice(loss_fn, data, params0, spec, base_cfg=cfg)
    on = run_lattice(
        loss_fn, data, params0, spec, base_cfg=cfg,
        obs=ObsConfig(diagnostics=True),
    )
    # base records: ON vs OFF is a cross-program comparison (the taps change
    # the compiled program), so tight allclose rather than bitwise — the
    # documented cross-program reduction wobble
    for f in ("e_com", "e_var", "grad_norm", "n_scheduled"):
        np.testing.assert_allclose(
            getattr(on, f), getattr(off, f), rtol=1e-6, err_msg=f
        )
    d = on.diag
    assert isinstance(d, RoundDiagnostics)
    grid_shape = (1, len(spec.policies), 1, 1, 2, spec.n_rounds)
    for f in d._fields:
        tap = np.asarray(getattr(d, f))
        assert tap.shape == grid_shape, f
        assert np.isfinite(tap).all(), f
    # entropy of an 8-device scheduling distribution lives in [0, log 8]
    assert (d.sched_entropy >= 0).all()
    assert (d.sched_entropy <= np.log(8) + 1e-5).all()
    # no EPS guard should clamp on this benign task
    assert (d.eps_clamps == 0).all()
    assert (d.noise_eff >= 0).all()
    assert (d.grad_norm_spread >= 0).all()


def test_diagnostics_repeat_retraces_zero_times():
    import dataclasses

    from repro.core.pofl import POFLConfig
    from repro.sim.engine import FUSED_POLICY, cached_engine
    from repro.sim.lattice import run_lattice

    loss_fn, data, params0 = _tiny_task()
    spec = _tiny_spec()
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    obs = ObsConfig(diagnostics=True)
    first = run_lattice(loss_fn, data, params0, spec, base_cfg=cfg, obs=obs)
    eng = cached_engine(
        loss_fn, data, dataclasses.replace(cfg, policy=FUSED_POLICY), obs=obs
    )
    traces, compiles = eng.n_lattice_traces, eng.n_compiles
    assert traces == 1 and compiles == 1
    repeat = run_lattice(loss_fn, data, params0, spec, base_cfg=cfg, obs=obs)
    assert eng.n_lattice_traces == traces  # ISSUE 6 acceptance: zero retraces
    assert eng.n_compiles == compiles
    for f in repeat.diag._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(repeat.diag, f)), np.asarray(getattr(first.diag, f))
        )


def test_fallback_lattice_diagnostics_match_fused():
    from repro.core.pofl import POFLConfig
    from repro.sim.lattice import run_lattice

    loss_fn, data, params0 = _tiny_task()
    spec = _tiny_spec(n_rounds=2)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    obs = ObsConfig(diagnostics=True)
    fused = run_lattice(loss_fn, data, params0, spec, base_cfg=cfg, obs=obs)
    fallback = run_lattice(
        loss_fn, data, params0, spec, base_cfg=cfg, obs=obs,
        fuse_policies=False,
    )
    for f in fused.diag._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(fused.diag, f)),
            np.asarray(getattr(fallback.diag, f)),
            err_msg=f,
        )


def test_lattice_emits_events_and_gate_passes(monkeypatch, tmp_path):
    from repro.core.pofl import POFLConfig
    from repro.sim.lattice import run_lattice

    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    loss_fn, data, params0 = _tiny_task()
    spec = _tiny_spec(n_rounds=2)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    run_lattice(loss_fn, data, params0, spec, base_cfg=cfg)
    run_lattice(loss_fn, data, params0, spec, base_cfg=cfg)  # warm repeat
    close_sink()

    summary = collect(read_events(str(tmp_path)))
    lat = summary["lattice"]
    assert len(lat) == 2
    cold, warm = lat
    assert cold["warm"] is False and cold["trace_delta"] == 1
    assert warm["warm"] is True and warm["trace_delta"] == 0
    assert warm["compile_delta"] == 0 and warm["engine_compiles"] == 1
    assert summary["spans"][(0, "lattice.sweep")]["count"] == 2
    assert summary["spans"][(0, "lattice.compile")]["count"] == 1
    assert gate_warm_lattice(summary) == []
    text = render(summary)
    assert "lattice.compile" in text and "lattice runs" in text
    # the module CLI agrees
    assert report_main([str(tmp_path), "--gate-warm-lattice"]) == 0


def test_report_gate_fails_on_warm_retrace(tmp_path, capsys):
    p = tmp_path / "events-p000of001-1.jsonl"
    bad = {
        "kind": "lattice", "name": "lattice.run", "process_index": 0,
        "cells": 4, "warm": True, "trace_delta": 1, "compile_delta": 1,
        "fused": True, "engine_compiles": 2,
    }
    p.write_text(json.dumps(bad) + "\n")
    assert report_main([str(tmp_path), "--gate-warm-lattice"]) == 1
    err = capsys.readouterr().err
    assert "re-traced" in err and "compiled programs" in err
    # and an empty sink dir is a gate failure too (nothing proven)
    empty = tmp_path / "empty"
    empty.mkdir()
    assert report_main([str(empty), "--gate-warm-lattice"]) == 1


def test_run_with_history_counts_traces_in_registry():
    from repro.core.pofl import POFLConfig, run_pofl

    loss_fn, data, params0 = _tiny_task()
    cfg = POFLConfig(n_devices=8, n_scheduled=3, seed=0)
    assert metric_value("engine.traces") == 0
    run_pofl(loss_fn, params0, data, cfg, n_rounds=3)
    traces = metric_value("engine.traces")
    assert traces >= 1
    run_pofl(loss_fn, params0, data, cfg, n_rounds=3)  # cached: no retrace
    assert metric_value("engine.traces") == traces


# --------------------------------------------------------------------------
# bench history satellite
# --------------------------------------------------------------------------


def test_bench_history_append_and_report(tmp_path, capsys):
    from benchmarks.report import history_table, load_history
    from benchmarks.run import append_history

    path = str(tmp_path / "hist.jsonl")
    entry = append_history({"cells": 15, "steady_cells_per_sec": 42.0}, path=path)
    assert entry["git_sha"] and entry["timestamp"]
    append_history({"cells": 15, "steady_cells_per_sec": 43.5}, path=path)
    hist = load_history(path)
    assert len(hist) == 2
    assert hist[0]["cells"] == 15
    assert hist[1]["steady_cells_per_sec"] == 43.5
    table = history_table(hist)
    assert "42.0" in table and "43.5" in table
    assert hist[0]["git_sha"] == entry["git_sha"]
    assert load_history(str(tmp_path / "missing.jsonl")) == []


# --------------------------------------------------------------------------
# the 2-process shared-sink harness
# --------------------------------------------------------------------------


@pytest.mark.distributed
def test_two_process_workers_write_one_sink_file_each(tmp_path):
    """ISSUE 6 acceptance: a 2-process launcher parity run under one shared
    ``REPRO_OBS_DIR`` produces exactly one JSONL per worker (rank stamps
    {0, 1} of 2) with matching lattice span/compile totals across ranks —
    SPMD workers run the same program, so their flight recordings agree."""
    obs_dir = str(tmp_path / "obs")
    out = str(tmp_path / "parity.npz")
    env = dict(
        os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu", REPRO_OBS_DIR=obs_dir
    )
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.distributed",
         "--procs", "2", "--devices-per-proc", "4",
         "--workload", "parity", "--out", out, "--n-rounds", "2",
         "--timeout", "450"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    if proc.returncode != 0:
        sys.stdout.write(proc.stdout[-8000:])
        sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed launcher failed"

    files = event_files(obs_dir)
    assert len(files) == 2, files
    names = sorted(os.path.basename(f) for f in files)
    assert names[0].startswith("events-p000of002-")
    assert names[1].startswith("events-p001of002-")

    summary = collect(read_events(obs_dir))
    assert summary["processes"] == {0, 1}
    per_rank = {}
    for rank in (0, 1):
        per_rank[rank] = {
            "compiles": summary["spans"].get((rank, "lattice.compile"), {}).get("count", 0),
            "sweeps": summary["spans"].get((rank, "lattice.sweep"), {}).get("count", 0),
            "gathers": summary["spans"].get((rank, "multihost.gather"), {}).get("count", 0),
            "lattice_events": [
                (e["warm"], e["trace_delta"]) for e in summary["lattice"]
                if e["process_index"] == rank
            ],
        }
    # SPMD: every rank compiled/swept/gathered the same number of times and
    # recorded the same cold/warm lattice sequence
    assert per_rank[0] == per_rank[1]
    assert per_rank[0]["sweeps"] == 3  # cold + warm repeat + fallback
    assert per_rank[0]["gathers"] >= 3
    # the warm repeat re-traced zero times on BOTH ranks
    assert gate_warm_lattice(summary) == []
