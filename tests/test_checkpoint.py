"""Checkpoint round-trip: params + optimizer state through npz."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import restore, save
from repro.models import api
from repro.optim.optimizers import adamw


def test_roundtrip(tmp_path):
    cfg = configs.reduced_config("qwen2-0.5b")
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    # one update so state is non-trivial
    grads = jax.tree.map(jnp.ones_like, params)
    params, opt_state = opt.update(grads, opt_state, params)

    path = str(tmp_path / "ckpt")
    save(path, step=7, params=params, opt_state=opt_state)

    p_t = jax.tree.map(jnp.zeros_like, params)
    o_t = jax.tree.map(jnp.zeros_like, opt_state)
    step, p2, o2 = restore(path, p_t, o_t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
