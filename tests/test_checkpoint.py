"""Checkpoint round-trip: params + optimizer state through npz — plus the
crash-atomicity contract ``sim.resilience`` leans on (a kill mid-save can
never tear a PUBLISHED npz; the meta sidecar lands before the npz commit)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import load_pytree, restore, save, save_pytree
from repro.models import api
from repro.optim.optimizers import adamw


def test_roundtrip(tmp_path):
    cfg = configs.reduced_config("qwen2-0.5b")
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    # one update so state is non-trivial
    grads = jax.tree.map(jnp.ones_like, params)
    params, opt_state = opt.update(grads, opt_state, params)

    path = str(tmp_path / "ckpt")
    save(path, step=7, params=params, opt_state=opt_state)

    p_t = jax.tree.map(jnp.zeros_like, params)
    o_t = jax.tree.map(jnp.zeros_like, opt_state)
    step, p2, o2 = restore(path, p_t, o_t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# crash-atomicity (the sim.resilience checkpoint contract)
# --------------------------------------------------------------------------


def test_suffix_and_suffixless_paths_are_the_same_checkpoint(tmp_path):
    """Save with '.npz', load without (and vice versa): one normalization
    rule, so the meta sidecar is always found next to its npz."""
    tree = {"a": jnp.arange(3.0)}
    save_pytree(str(tmp_path / "ck.npz"), tree, metadata={"step": 3})
    out = load_pytree(str(tmp_path / "ck"), {"a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["a"]), [0.0, 1.0, 2.0])
    assert json.load(open(tmp_path / "ck.meta.json"))["step"] == 3

    save_pytree(str(tmp_path / "ck2"), tree)
    load_pytree(str(tmp_path / "ck2.npz"), {"a": jnp.zeros(3)})


def test_failed_save_keeps_published_checkpoint_intact(tmp_path, monkeypatch):
    """Torn-file regression: a save that dies mid-write must leave the
    previously PUBLISHED npz loadable and byte-identical, and no tmp
    litter behind."""
    path = str(tmp_path / "ck")
    save_pytree(path, {"a": jnp.full(4, 7.0)}, metadata={"gen": 1})

    real_savez = np.savez

    def dying_savez(f, **kw):
        f.write(b"garbage-partial-write")  # tear the stream, then die
        raise OSError("disk died mid-save")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(OSError, match="disk died"):
        save_pytree(path, {"a": jnp.full(4, 9.0)}, metadata={"gen": 2})
    monkeypatch.setattr(np, "savez", real_savez)

    out = load_pytree(path, {"a": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(4, 7.0))
    assert not [n for n in os.listdir(tmp_path) if ".tmp-" in n]


def test_meta_published_before_npz_commit(tmp_path, monkeypatch):
    """The write-order contract: discovery keys on npz presence, so the
    ``os.replace`` that publishes the meta sidecar must happen strictly
    before the one that commits the npz."""
    order = []
    real_replace = os.replace

    def recording_replace(src, dst):
        order.append(dst)
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", recording_replace)
    save_pytree(str(tmp_path / "ck"), {"a": jnp.zeros(2)}, metadata={"t": 1})
    assert [os.path.basename(p) for p in order] == ["ck.meta.json", "ck.npz"]


def test_truncated_npz_fails_loudly(tmp_path):
    """A file torn by anything OTHER than save_pytree (partial copy, bad
    disk) must raise on load, never half-read."""
    path = str(tmp_path / "ck")
    save_pytree(path, {"a": jnp.arange(100.0)})
    npz = tmp_path / "ck.npz"
    npz.write_bytes(npz.read_bytes()[:40])  # tear it
    with pytest.raises(Exception):
        load_pytree(path, {"a": jnp.zeros(100)})
