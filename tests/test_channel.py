"""Channel-model tests (paper Sec. V-A constants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig, ChannelState, device_distances, path_loss


def test_path_loss_monotone_decreasing():
    cfg = ChannelConfig()
    d = jnp.linspace(10.0, 50.0, 16)
    g = path_loss(cfg, d)
    assert jnp.all(g > 0)
    assert jnp.all(jnp.diff(g) < 0), "path loss gain must decrease with distance"


def test_distances_in_range():
    cfg = ChannelConfig(n_devices=100)
    d = device_distances(cfg, jax.random.PRNGKey(0))
    assert d.shape == (100,)
    assert float(d.min()) >= cfg.d_min and float(d.max()) <= cfg.d_max


def test_rayleigh_fading_statistics():
    """E[|h|^2] = g_i and h is zero-mean complex (CN(0, g))."""
    cfg = ChannelConfig(n_devices=8)
    state = ChannelState.create(cfg, jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    hs = jax.vmap(state.sample)(keys)  # (4000, 8)
    emp_power = jnp.mean(jnp.abs(hs) ** 2, axis=0)
    np.testing.assert_allclose(emp_power, state.gains, rtol=0.1)
    emp_mean = jnp.abs(jnp.mean(hs, axis=0))
    assert float(emp_mean.max()) < 3e-2 * float(jnp.sqrt(state.gains.max())) * 10


def test_channel_is_block_fading_iid_over_rounds():
    cfg = ChannelConfig(n_devices=4)
    state = ChannelState.create(cfg, jax.random.PRNGKey(0))
    h1 = state.sample(jax.random.PRNGKey(1))
    h2 = state.sample(jax.random.PRNGKey(2))
    assert not np.allclose(h1, h2)
    # same key -> reproducible
    np.testing.assert_array_equal(h1, state.sample(jax.random.PRNGKey(1)))
