"""Model-substrate equivalence tests: the production (chunked / scatter)
paths must match their naive references exactly."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import layers as L
from repro.models import transformer
from repro.models.config import ModelConfig, MoEConfig


def _attn_cfg(**kw) -> ModelConfig:
    base = dict(
        name="t", arch_type="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128,
    )
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------------
# chunked attention == unchunked attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 7])
def test_chunked_attention_matches_unchunked(window):
    cfg = _attn_cfg(sliding_window=window)
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

    q, k, v = L._qkv(p, x, cfg, jnp.float32)
    pos = jnp.arange(32)[None, :]
    q = L.apply_rope(q, pos, cfg.rope_theta).reshape(2, 32, 2, 2, 16)
    k = L.apply_rope(k, pos, cfg.rope_theta)

    full = L._attention_core(
        q, k, v, causal=True, sliding_window=window, q_offset=0,
        dtype=jnp.float32, q_chunk=None,
    )
    chunked = L._attention_core(
        q, k, v, causal=True, sliding_window=window, q_offset=0,
        dtype=jnp.float32, q_chunk=8,
    )
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=1e-5, atol=1e-6
    )


# --------------------------------------------------------------------------
# chunked cross-entropy == monolithic cross-entropy
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seq", [9, 16, 33])  # exercises padding
def test_chunked_ce_matches_monolithic(seq):
    cfg = _attn_cfg(vocab_size=100)
    key = jax.random.PRNGKey(0)
    head = jax.random.normal(key, (cfg.d_model, cfg.vocab_padded)) * 0.1
    params = {"lm_head": head}
    x = jax.random.normal(jax.random.PRNGKey(1), (3, seq, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (3, seq), 0, 100)

    # monolithic
    logits = transformer.logits_from_hidden(params, cfg, x[:, :-1], jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tokens[:, 1:][..., None], axis=-1)[..., 0]
    want = nll.mean(axis=-1)

    old = transformer.CE_CHUNK
    transformer.CE_CHUNK = 8
    try:
        got = transformer.chunked_ce(params, cfg, x, tokens, jnp.float32)
    finally:
        transformer.CE_CHUNK = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# scatter-dispatch MoE == dense per-token reference
# --------------------------------------------------------------------------


def _moe_cfg(e=4, k=2, cf=8.0):
    return _attn_cfg(
        arch_type="moe",
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=32, capacity_factor=cf),
    )


def _moe_dense_ref(params, x, cfg):
    """Reference: every expert on every token, gate-combined (no capacity)."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, moe.top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["w_gate"]))
    u = jnp.einsum("td,edf->tef", xt, params["w_in"])
    ye = jnp.einsum("tef,efd->ted", g * u, params["w_out"])  # (T, E, D)
    gates = jnp.zeros((xt.shape[0], moe.n_experts)).at[
        jnp.arange(xt.shape[0])[:, None], gate_idx
    ].set(gate_vals)
    return jnp.einsum("te,ted->td", gates, ye).reshape(b, s, d)


def test_moe_scatter_matches_dense_ref():
    cfg = _moe_cfg(cf=8.0)  # capacity high enough that nothing is dropped
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, aux = L.moe_fwd(p, x, cfg, jnp.float32)
    want = _moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor ≈ 1/E·k the buffer overflows: output is damped
    but finite, and aux loss still computes."""
    cfg = _moe_cfg(cf=0.25)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, aux = L.moe_fwd(p, x, cfg, jnp.float32)
    assert bool(jnp.all(jnp.isfinite(got)))
    dense = _moe_dense_ref(p, x, cfg)
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(dense)) * 1.5


def test_moe_grouping_invariance():
    """Group size must not change results when capacity is ample."""
    cfg = _moe_cfg(cf=8.0)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    old = L.MOE_GROUP_SIZE
    try:
        L.MOE_GROUP_SIZE = 16
        a, _ = L.moe_fwd(p, x, cfg, jnp.float32)
        L.MOE_GROUP_SIZE = 64
        b, _ = L.moe_fwd(p, x, cfg, jnp.float32)
    finally:
        L.MOE_GROUP_SIZE = old
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------------------
# property tests (hypothesis)
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(4, 24),
    window=st.one_of(st.none(), st.integers(1, 30)),
    offset=st.integers(0, 8),
)
def test_attention_mask_properties(s, window, offset):
    """Causality: row i allows exactly min(i+off+1, window) keys (clipped)."""
    m = L.attention_scores_mask(s, s + offset, q_offset=offset, causal=True,
                                sliding_window=window)
    m = np.asarray(m)
    for i in range(s):
        allowed = np.flatnonzero(m[i])
        assert allowed.size > 0
        assert allowed.max() == i + offset  # newest visible key = self
        if window is not None:
            assert allowed.min() >= i + offset - window + 1
            assert allowed.size == min(i + offset + 1, window)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3), s=st.integers(2, 6), data=st.data(),
)
def test_rope_preserves_norm_and_relativity(b, s, data):
    """RoPE is an isometry, and q·k depends only on relative positions."""
    dh = 16
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**30)))
    x = jax.random.normal(key, (b, s, 2, dh))
    pos = jnp.arange(s)[None, :]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-4, atol=1e-5,
    )
    # relativity: shift all positions by a constant → dot products unchanged
    shift = data.draw(st.integers(1, 100))
    y2 = L.apply_rope(x, pos + shift, 10000.0)
    dots1 = jnp.einsum("bqhd,bkhd->bhqk", y, y)
    dots2 = jnp.einsum("bqhd,bkhd->bhqk", y2, y2)
    np.testing.assert_allclose(np.asarray(dots1), np.asarray(dots2), rtol=1e-3, atol=1e-3)


def test_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces prefill logits position by position."""
    from repro.models import api

    cfg = configs.reduced_config("phi4-mini-3.8b")
    params = api.model_init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)

    logits_full, _ = transformer.forward(params, cfg, tokens)

    prefix = {"tokens": tokens[:, :4]}
    lp, cache = api.model_prefill(params, cfg, prefix)
    from repro.models.cache import pad_cache

    cache = pad_cache(cache, 12)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(logits_full[:, 3]), rtol=2e-4, atol=2e-4
    )
    for t in range(4, 12):
        lt, cache = api.model_decode(
            params, cfg, tokens[:, t:t + 1], cache, jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(lt[:, 0]), np.asarray(logits_full[:, t]),
            rtol=2e-4, atol=2e-4,
        )
