"""Launcher: run the multi-device test modules in a subprocess with 8 host
devices (XLA locks the device count at first jax init, so the main pytest
process — which must see 1 device for the smoke tests — cannot host them)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
MULTI_DEVICE_MODULES = ["test_distributed.py", "test_dryrun_small.py"]


@pytest.mark.parametrize("module", MULTI_DEVICE_MODULES)
def test_multi_device_module(module):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", os.path.join(HERE, module), "-q",
         "-p", "no:cacheprovider"],
        env=env, capture_output=True, text=True, timeout=2400,
    )
    if proc.returncode != 0:
        sys.stdout.write(proc.stdout[-8000:])
        sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"{module} failed in 8-device subprocess"
