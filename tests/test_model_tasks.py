"""Model-task battery (ISSUE 9 tentpole pin): real pytree models as
first-class lattice tasks.

Contracts pinned here:

  * ``jax.flatten_util.ravel_pytree`` round-trips BOTH task pytrees (logreg
    dict, 4-conv CNN nested dict) bit-identically, and ``ModelTask.dim``
    is the raveled length (CNN ≈ 2.6×10⁵ — the paper-scale model).
  * ``make_model_task`` is memoized: equal arguments return the SAME object,
    so task identity keys the engine cache and a rebuilt task re-traces ZERO
    times on a repeat sweep.
  * Seed-pinned golden accuracy/loss trajectories for the logreg task on
    Dirichlet-sized (padded, heterogeneous) shards, with MONOTONE-improving
    accuracy under both scheduling policies; the fused multi-policy program
    and the ``fuse_policies=False`` fallback are BIT-identical, including
    the structured ``eval`` subtree.
  * The ``eval`` record contract (the PR-6 ``diag=None`` trick, third
    application): a ``TaskEval`` eval_fn grows ``LatticeRecords.eval``
    (an ``EvalRecord`` of curves whose loss/acc equal the legacy fields
    bitwise); any other eval_fn — or none — leaves it ``None``, keeping the
    record pytree EMPTY there and every pinned trajectory unchanged.
  * Eval masking under padded shards: pad rows poisoned with wrong labels
    (``data.synthetic.pad_with_wrong_labels``) must not move loss, accuracy,
    or the correct count when ``n_valid`` marks the true prefix — for both
    ``TaskEval`` and the legacy ``models.small.make_eval_fn`` seam — and an
    eval WITHOUT the mask provably shifts (the poison bites).
  * The CNN task (D = 258 634) runs a multi-policy lattice as ONE trace /
    ONE compile with monotone-improving pinned accuracy, and (under the
    sharded-8dev CI job) a 2-D ``(cells, model) = (4, 2)`` mesh reproduces
    the unsharded run — decisions exact, float channels at the documented
    ≤1-ULP cross-program tolerance (the PR-7 carve-out).

CNN sizing note: on single-core CPU the conv grads inside the engine's
``lax.scan`` lower to XLA's naive (non-Eigen) loops — ~0.5 s per train
sample per round — so the CNN cells here are deliberately tiny (few devices,
small batches, handful of rounds). The physics is in the logreg battery; the
CNN cells pin the paper-scale pytree plumbing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig
from repro.data.synthetic import (
    make_classification_dataset,
    pad_with_wrong_labels,
)
from repro.models import small
from repro.sim import (
    FUSED_POLICY,
    EvalRecord,
    LatticeSpec,
    TaskEval,
    cached_engine,
    make_cell_model_mesh,
    make_model_task,
    run_lattice,
)

N_VISIBLE = len(jax.devices())
needs_8_devices = pytest.mark.skipif(
    N_VISIBLE < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

_RECORD_FIELDS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")
_DECISION_FIELDS = ("n_scheduled", "loss", "acc")  # cross-program exact
_FLOAT_FIELDS = ("e_com", "e_var", "grad_norm")    # cross-program ≤1-ULP


# --------------------------------------------------------------------------
# the logreg battery configuration + seed-pinned goldens
# --------------------------------------------------------------------------
# Regenerate (after an INTENTIONAL semantics change only) by running
# examples/model_tasks.py — it prints exactly these curves.

LOGREG_SPEC = LatticeSpec(
    policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
    seeds=(0,), n_rounds=6, eval_every=2,
)
LOGREG_CFG = dict(n_devices=8, n_scheduled=3, batch_size=8, lr0=0.1)
LOGREG_EVAL_ROUNDS = [0, 2, 4, 5]

GOLDEN_LOGREG = {
    "pofl": {
        "acc": [0.265625, 0.65625, 0.78125, 0.8984375],
        "loss": [2.293933868408203, 2.2775325775146484, 2.2660269737243652, 2.2581968307495117],
        "n_correct": [68.0, 168.0, 200.0, 230.0],
    },
    "channel": {
        "acc": [0.08203125, 0.26953125, 0.48828125, 0.625],
        "loss": [2.299790382385254, 2.2923011779785156, 2.283151626586914, 2.276052236557007],
        "n_correct": [21.0, 69.0, 125.0, 160.0],
    },
}


def _logreg_task():
    """The battery task: 8 Dirichlet-sized (PADDED heterogeneous) shards of
    the 784-dim synthetic MNIST stand-in. Memoized — every test shares the
    object, and with it the engine-cache entry."""
    return make_model_task(
        "logreg", n_devices=8, partition="dirichlet_sized",
        n_train=640, n_test=256, seed=0,
    )


def _run_logreg(**kw):
    task = _logreg_task()
    return task, run_lattice(
        task.loss_fn, task.data, task.params0, LOGREG_SPEC,
        base_cfg=POFLConfig(**LOGREG_CFG), eval_fn=kw.pop("eval_fn", task.eval),
        **kw,
    )


def _fused_counters(task, cfg):
    """(n_lattice_traces, n_compiles) of the fused-policy engine. Must be
    read in the SAME cache epoch as the run — conftest's autouse
    ``_fresh_engine_cache`` clears engines between tests, so the fixtures
    below capture counters right after their ``run_lattice`` calls."""
    eng = cached_engine(
        task.loss_fn, task.data, POFLConfig(policy=FUSED_POLICY, **cfg),
        eval_fn=task.eval,
    )
    return eng.n_lattice_traces, eng.n_compiles


@pytest.fixture(scope="module")
def logreg_recs():
    task, recs = _run_logreg()
    counters = _fused_counters(task, LOGREG_CFG)
    # the repeat sweep over a REBUILT task, still inside this cache epoch
    task2 = make_model_task(
        "logreg", n_devices=8, partition="dirichlet_sized",
        n_train=640, n_test=256, seed=0,
    )
    rebuilt_is_same = task2 is task
    recs2 = run_lattice(
        task2.loss_fn, task2.data, task2.params0, LOGREG_SPEC,
        base_cfg=POFLConfig(**LOGREG_CFG), eval_fn=task2.eval,
    )
    counters_repeat = _fused_counters(task, LOGREG_CFG)
    return {
        "task": task, "recs": recs, "recs_repeat": recs2,
        "counters": counters, "counters_repeat": counters_repeat,
        "rebuilt_is_same": rebuilt_is_same,
    }


# --------------------------------------------------------------------------
# ravel/unravel round-trip
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind,expect_dim", [("logreg", 7850), ("cnn", 258634)])
def test_ravel_roundtrip_bit_identity(kind, expect_dim):
    """ravel_pytree is a bijection on both task pytrees: unravel(ravel(p))
    equals p leaf-for-leaf BITWISE, and dim is the raveled length."""
    task = make_model_task(
        kind, n_devices=2, partition="shards", n_train=40, n_test=16, seed=0
    )
    assert task.dim == expect_dim
    flat = task.ravel(task.params0)
    assert flat.shape == (task.dim,)
    back = task.unravel(flat)
    assert (
        jax.tree_util.tree_structure(back)
        == jax.tree_util.tree_structure(task.params0)
    )
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(task.params0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the flat view round-trips too (ravel ∘ unravel = id on (D,))
    np.testing.assert_array_equal(
        np.asarray(task.ravel(back)), np.asarray(flat)
    )
    # the flat-space loss closure IS the pytree loss at raveled weights
    x = jnp.asarray(task.data.features[0, :4])
    y = jnp.asarray(task.data.labels[0, :4])
    np.testing.assert_array_equal(
        np.asarray(task.flat_loss_fn()(flat, x, y)),
        np.asarray(task.loss_fn(task.params0, x, y)),
    )


def test_make_model_task_memoized_identity_and_validation():
    t1 = make_model_task("logreg", n_devices=4, n_train=80, n_test=16, seed=3)
    t2 = make_model_task("logreg", n_devices=4, n_train=80, n_test=16, seed=3)
    assert t1 is t2  # identity → stable engine-cache key
    t3 = make_model_task("logreg", n_devices=4, n_train=80, n_test=16, seed=4)
    assert t3 is not t1
    with pytest.raises(ValueError, match="unknown task"):
        make_model_task("mlp", n_train=80, n_test=16)
    with pytest.raises(ValueError, match="unknown partition"):
        make_model_task("logreg", partition="byzantine", n_train=80, n_test=16)
    with pytest.raises(ValueError, match="dim override"):
        make_model_task("cnn", n_train=80, n_test=16, dim=64)


# --------------------------------------------------------------------------
# eval masking under padded test rows (the pad-poisoning regression)
# --------------------------------------------------------------------------


def _poisoned_eval_setup():
    key = jax.random.PRNGKey(9)
    k_data, k_init = jax.random.split(key)
    x, y = make_classification_dataset("mnist_like", 64, k_data)
    xp, yp = pad_with_wrong_labels(x, y, n_pad=32)
    params = small.init_logreg(k_init)
    return x, y, xp, yp, params


def test_task_eval_masks_poisoned_pad_rows():
    """A TaskEval whose n_valid marks the true prefix returns EXACTLY the
    clean-set record on a pad-poisoned test set; without the mask the
    poison provably shifts accuracy (the regression this battery pins)."""
    x, y, xp, yp, params = _poisoned_eval_setup()
    clean = TaskEval(small.logreg_logits, x, y).record(params)
    masked = TaskEval(small.logreg_logits, xp, yp, n_valid=64).record(params)
    for f in EvalRecord._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(masked, f)), np.asarray(getattr(clean, f)),
            err_msg=f,
        )
    # the denominator is pinned: acc ≡ n_correct / n_valid
    assert float(masked.acc) == float(masked.n_correct) / 64
    # and an UNMASKED eval counts the poisoned rows — the bug this catches
    leaky = TaskEval(small.logreg_logits, xp, yp).record(params)
    assert float(leaky.acc) != float(clean.acc)
    # __call__ is the legacy (loss, acc) view of the same record
    loss, acc = TaskEval(small.logreg_logits, xp, yp, n_valid=64)(params)
    np.testing.assert_array_equal(np.asarray(loss), np.asarray(masked.loss))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(masked.acc))


def test_legacy_make_eval_fn_masks_poisoned_pad_rows():
    """The same valid-prefix contract on the historical ``make_eval_fn``
    seam: n_valid slices the poison away; default None keeps the historical
    whole-set eval bit-identical."""
    x, y, xp, yp, params = _poisoned_eval_setup()
    ev_clean = small.make_eval_fn(small.logreg_logits, small.logreg_loss, x, y)
    ev_mask = small.make_eval_fn(
        small.logreg_logits, small.logreg_loss, xp, yp, n_valid=64
    )
    l0, a0 = ev_clean(params)
    l1, a1 = ev_mask(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l0))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a0))
    ev_leak = small.make_eval_fn(small.logreg_logits, small.logreg_loss, xp, yp)
    _, a_leak = ev_leak(params)
    assert float(a_leak) != float(a0)


def test_eval_n_valid_validation():
    x, y, xp, yp, _ = _poisoned_eval_setup()
    with pytest.raises(ValueError, match="n_valid"):
        TaskEval(small.logreg_logits, x, y, n_valid=0)
    with pytest.raises(ValueError, match="n_valid"):
        TaskEval(small.logreg_logits, x, y, n_valid=65)
    with pytest.raises(ValueError, match="n_valid"):
        small.make_eval_fn(
            small.logreg_logits, small.logreg_loss, x, y, n_valid=65
        )


# --------------------------------------------------------------------------
# the logreg golden battery
# --------------------------------------------------------------------------


def test_logreg_task_shards_are_heterogeneous():
    task = _logreg_task()
    assert task.data.n_samples is not None  # Dirichlet-sized → padded shards
    sizes = np.asarray(task.data.n_samples)
    assert sizes.min() >= 1 and sizes.max() > sizes.min()
    assert task.dim == 7850


def test_logreg_golden_accuracy_curves(logreg_recs):
    """Seed-pinned accuracy/loss trajectories for both policies, with
    MONOTONE-improving accuracy (the learning signal the synthetic task is
    tuned for) and pofl dominating channel-only scheduling."""
    recs = logreg_recs["recs"]
    assert recs.eval_rounds.tolist() == LOGREG_EVAL_ROUNDS
    assert isinstance(recs.eval, EvalRecord)
    assert recs.eval.acc.shape == (1, 2, 1, 1, 1, len(LOGREG_EVAL_ROUNDS))
    for pi, pol in enumerate(LOGREG_SPEC.policies):
        exp = GOLDEN_LOGREG[pol]
        acc = np.asarray(recs.eval.acc[0, pi, 0, 0, 0])
        np.testing.assert_allclose(acc, exp["acc"], rtol=1e-5, err_msg=pol)
        np.testing.assert_allclose(
            np.asarray(recs.eval.loss[0, pi, 0, 0, 0]), exp["loss"],
            rtol=1e-5, err_msg=pol,
        )
        # n_correct is a COUNT: pin it exactly (the accuracy denominator)
        np.testing.assert_array_equal(
            np.asarray(recs.eval.n_correct[0, pi, 0, 0, 0]),
            np.asarray(exp["n_correct"], np.float32), err_msg=pol,
        )
        assert np.all(np.diff(acc) >= 0) and acc[-1] > acc[0], pol
        assert np.all(np.diff(np.asarray(exp["loss"])) < 0), pol
    # gradient-importance-aware scheduling beats channel-only at every point
    assert np.all(
        np.asarray(recs.eval.acc[0, 0, 0, 0, 0])
        > np.asarray(recs.eval.acc[0, 1, 0, 0, 0])
    )


def test_eval_subtree_matches_legacy_fields(logreg_recs):
    """The structured subtree and the always-present loss/acc fields are the
    SAME computation: bitwise equal curves, and acc ≡ n_correct / n_valid
    (no pad rows of the padded test set leak into the denominator)."""
    task, recs = logreg_recs["task"], logreg_recs["recs"]
    np.testing.assert_array_equal(recs.eval.acc, recs.acc)
    np.testing.assert_array_equal(recs.eval.loss, recs.loss)
    np.testing.assert_array_equal(
        recs.eval.acc, recs.eval.n_correct / np.float32(task.eval.n_valid)
    )


def test_fused_matches_fallback_bitwise(logreg_recs):
    """fuse_policies=False (per-policy compiles, constant policy axis) is
    BIT-identical to the fused multi-policy program — eval subtree included
    (same contract the synthetic battery pins in test_fused_lattice.py)."""
    recs = logreg_recs["recs"]
    _, recs_fb = _run_logreg(fuse_policies=False)
    for f in _RECORD_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(recs, f)), np.asarray(getattr(recs_fb, f)),
            err_msg=f,
        )
    for f in EvalRecord._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(recs.eval, f)),
            np.asarray(getattr(recs_fb.eval, f)), err_msg=f,
        )


def test_repeat_sweep_zero_retraces_one_compile(logreg_recs):
    """make_model_task memoization closes the retrace loop: a REBUILT task
    (same arguments) is the same object, so the repeat sweep hits the same
    engine — n_lattice_traces and n_compiles stay at 1, records bitwise.
    (Counters were captured inside the fixture's cache epoch; see
    ``_fused_counters``.)"""
    assert logreg_recs["counters"] == (1, 1)
    assert logreg_recs["rebuilt_is_same"]
    assert logreg_recs["counters_repeat"] == (1, 1)
    recs, recs2 = logreg_recs["recs"], logreg_recs["recs_repeat"]
    for f in EvalRecord._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(recs.eval, f)),
            np.asarray(getattr(recs2.eval, f)), err_msg=f,
        )


def test_eval_off_and_legacy_eval_keep_subtree_none(logreg_recs):
    """The OFF-by-default contract: no eval_fn → ``eval is None`` (empty
    record pytree, E = 0); a legacy non-TaskEval eval_fn → curves present
    but STILL ``eval is None`` — only a TaskEval grows the subtree. Either
    way the training trajectory is unperturbed (eval never touches the PRNG
    chain): decisions match the TaskEval run exactly."""
    task, recs = logreg_recs["task"], logreg_recs["recs"]
    _, recs_off = _run_logreg(eval_fn=None)
    assert recs_off.eval is None
    assert recs_off.loss.shape[-1] == 0 and recs_off.eval_rounds.size == 0
    np.testing.assert_array_equal(recs_off.n_scheduled, recs.n_scheduled)
    np.testing.assert_array_equal(recs_off.e_com, recs.e_com)

    legacy_ev = small.make_eval_fn(
        task.logits_fn, task.loss_fn, task.eval.x_test, task.eval.y_test,
        batch=256,
    )
    _, recs_leg = _run_logreg(eval_fn=legacy_ev)
    assert recs_leg.eval is None  # only a TaskEval grows the subtree
    assert recs_leg.loss.shape[-1] == len(LOGREG_EVAL_ROUNDS)
    np.testing.assert_array_equal(recs_leg.n_scheduled, recs.n_scheduled)
    # same eval semantics, different reduction program → ≤1-ULP tolerance
    np.testing.assert_allclose(recs_leg.acc, recs.acc, rtol=1e-6)
    np.testing.assert_allclose(recs_leg.loss, recs.loss, rtol=1e-6)


# --------------------------------------------------------------------------
# the CNN battery: the paper-scale pytree (D = 258 634) on the lattice
# --------------------------------------------------------------------------
# Deliberately tiny cells (see the module docstring's CNN sizing note):
# 2 policies × 4 devices × 3 rounds ≈ 1 min on single-core CPU.
# channel_bias=1.0 gives the GAP-CNN a pooling-survivable class signal so
# the few-round curves show real learning. Regenerate the goldens with
# examples/model_tasks.py --task cnn.

CNN_SPEC = LatticeSpec(
    policies=("pofl", "channel"), noise_powers=(1e-11,), alphas=(0.1,),
    seeds=(0,), n_rounds=3, eval_every=2,
)
CNN_CFG = dict(n_devices=4, n_scheduled=2, batch_size=4, lr0=0.1)
CNN_EVAL_ROUNDS = [0, 2]

GOLDEN_CNN = {
    "pofl": {
        "acc": [0.0833333358168602, 0.5],
        "loss": [2.979822874069214, 1.9893426895141602],
        "n_correct": [2.0, 12.0],
    },
    "channel": {
        "acc": [0.0416666679084301, 0.1666666716337204],
        "loss": [2.968735456466675, 2.5682239532470703],
        "n_correct": [1.0, 4.0],
    },
}


def _cnn_task():
    return make_model_task(
        "cnn", n_devices=4, partition="dirichlet_sized",
        n_train=64, n_test=24, seed=0, channel_bias=1.0,
    )


def _run_cnn(**kw):
    task = _cnn_task()
    return task, run_lattice(
        task.loss_fn, task.data, task.params0, CNN_SPEC,
        base_cfg=POFLConfig(**CNN_CFG), eval_fn=task.eval, **kw,
    )


@pytest.fixture(scope="module")
def cnn_recs():
    task, recs = _run_cnn()
    return {"task": task, "recs": recs,
            "counters": _fused_counters(task, CNN_CFG)}


def test_cnn_lattice_one_trace_one_compile_monotone_goldens(cnn_recs):
    """The PR acceptance pin: a multi-policy lattice over the full 4-conv
    CNN pytree (D = 258 634 raveled params) is ONE trace / ONE compile, and
    the seed-pinned accuracy curves improve monotonically under both
    policies with gradient-importance-aware scheduling dominating."""
    task, recs = cnn_recs["task"], cnn_recs["recs"]
    assert task.dim == 258634
    assert cnn_recs["counters"] == (1, 1)

    assert recs.eval_rounds.tolist() == CNN_EVAL_ROUNDS
    assert isinstance(recs.eval, EvalRecord)
    for pi, pol in enumerate(CNN_SPEC.policies):
        exp = GOLDEN_CNN[pol]
        acc = np.asarray(recs.eval.acc[0, pi, 0, 0, 0])
        np.testing.assert_allclose(acc, exp["acc"], rtol=1e-5, err_msg=pol)
        np.testing.assert_allclose(
            np.asarray(recs.eval.loss[0, pi, 0, 0, 0]), exp["loss"],
            rtol=1e-5, err_msg=pol,
        )
        np.testing.assert_array_equal(
            np.asarray(recs.eval.n_correct[0, pi, 0, 0, 0]),
            np.asarray(exp["n_correct"], np.float32), err_msg=pol,
        )
        assert np.all(np.diff(acc) > 0), pol
        assert np.all(np.diff(np.asarray(exp["loss"])) < 0), pol
    assert np.all(
        np.asarray(recs.eval.acc[0, 0, 0, 0, 0])
        > np.asarray(recs.eval.acc[0, 1, 0, 0, 0])
    )
    # the subtree and legacy fields remain one computation at CNN scale
    np.testing.assert_array_equal(recs.eval.acc, recs.acc)
    np.testing.assert_array_equal(recs.eval.loss, recs.loss)


@needs_8_devices
def test_cnn_sharded_2d_mesh_parity(cnn_recs):
    """The (cells, model) = (4, 2) mesh shards the raveled CNN dimension
    (D_local ≈ 1.3×10⁵ per model shard) and reproduces the unsharded run:
    decisions exact, float channels within the documented ≤1-ULP
    cross-program reduction tolerance (the PR-7 carve-out)."""
    recs = cnn_recs["recs"]
    _, sharded = _run_cnn(mesh=make_cell_model_mesh(4, 2))
    np.testing.assert_array_equal(sharded.eval_rounds, recs.eval_rounds)
    for f in _DECISION_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(sharded, f)), np.asarray(getattr(recs, f)),
            err_msg=f,
        )
    for f in _FLOAT_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(sharded, f)), np.asarray(getattr(recs, f)),
            rtol=1e-5, atol=1e-12, err_msg=f,
        )
    for f in EvalRecord._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(sharded.eval, f)),
            np.asarray(getattr(recs.eval, f)), err_msg=f,
        )


def test_run_with_history_takes_task_eval():
    """The chunked run_pofl driver accepts a TaskEval as its host-side
    eval_fn (the legacy (loss, acc) __call__ seam) and the history improves."""
    task = _logreg_task()
    cfg = POFLConfig(policy="pofl", **LOGREG_CFG)
    eng = cached_engine(task.loss_fn, task.data, cfg, eval_fn=task.eval)
    _, hist = eng.run_with_history(
        task.params0, n_rounds=6, eval_fn=task.eval, eval_every=2, seed=0
    )
    assert hist.test_round == LOGREG_EVAL_ROUNDS
    acc = np.asarray(hist.test_acc)
    assert np.all(np.diff(acc) >= 0) and acc[-1] > acc[0]
