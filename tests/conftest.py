"""Shared fixtures: engine-cache hygiene + opt-in persistent compile cache.

The cross-call engine cache (``repro.sim.engine``) is process-global, so a
test asserting on ``engine_cache_stats()`` counters (or on which engine a
call returns) would otherwise depend on which tests ran before it. Every
test starts from an empty cache with zeroed counters; caching behavior is
still fully exercised *within* each test (that is what the cache tests do).

Persistent compiles: when ``REPRO_COMPILE_CACHE=<dir>`` is exported, every
XLA compile in the test session is persisted there / reloaded from there
(``repro.sim.compile_cache``) — CI runs the compile-heavy suites against an
``actions/cache``'d directory. ``REPRO_COMPILE_CACHE_EXPECT_HITS=1``
additionally makes the session FAIL unless at least one compile was served
from the persistent cache — the warm-second-run assertion of the CI jobs.
"""
from __future__ import annotations

import os

import pytest

from repro.obs import close_sink, reset_metrics
from repro.sim import (
    enable_compile_cache,
    engine_cache_stats,
    persistent_cache_counters,
    reset_engine_cache,
)

_CACHE_DIR = enable_compile_cache()  # no-op (None) unless the env var is set


@pytest.fixture(scope="session", autouse=True)
def _engine_cache_clean_at_session_start():
    """Importing test modules (or plugins) must not populate the cache —
    a dirty cache at collection time would mean import-time engine builds."""
    stats = engine_cache_stats()
    assert stats == {"hits": 0, "misses": 0, "size": 0}, (
        f"engine cache dirty at session start: {stats}"
    )
    yield


@pytest.fixture(autouse=True)
def _fresh_engine_cache():
    """Order-independence: every test sees an empty engine cache and zeroed
    obs span/engine/lattice counters.

    PREFIX resets only: the ``compile_cache.`` registry namespace is
    process-lifetime — the ``REPRO_COMPILE_CACHE_EXPECT_HITS`` session-end
    guard below reads it across the whole run, so no per-test reset (or
    unscoped ``reset_metrics()``) may touch it.
    """
    reset_engine_cache()  # clears engines + the engine_cache. namespace
    for prefix in ("span.", "engine.", "lattice.", "multihost."):
        reset_metrics(prefix)
    yield
    close_sink()  # drop per-dir handles so tmp sink dirs can be removed


@pytest.fixture(scope="session", autouse=True)
def _persistent_cache_hits_guard():
    """With ``REPRO_COMPILE_CACHE_EXPECT_HITS`` set, a session that never
    hit the persistent compilation cache is a FAILURE — CI's warm re-run
    proves compiles actually survive across processes."""
    yield
    counters = persistent_cache_counters()
    if _CACHE_DIR:
        print(
            f"\npersistent compile cache {_CACHE_DIR}: "
            f"{counters['hits']} hit(s), {counters['misses']} miss(es)"
        )
    if os.environ.get("REPRO_COMPILE_CACHE_EXPECT_HITS"):
        assert _CACHE_DIR, (
            "REPRO_COMPILE_CACHE_EXPECT_HITS needs REPRO_COMPILE_CACHE set"
        )
        assert counters["hits"] > 0, (
            "expected persistent compilation-cache hits on this warm run, "
            f"got none (counters: {counters}, dir: {_CACHE_DIR})"
        )
