"""Shared fixtures: engine-cache hygiene.

The cross-call engine cache (``repro.sim.engine``) is process-global, so a
test asserting on ``engine_cache_stats()`` counters (or on which engine a
call returns) would otherwise depend on which tests ran before it. Every
test starts from an empty cache with zeroed counters; caching behavior is
still fully exercised *within* each test (that is what the cache tests do).
"""
from __future__ import annotations

import pytest

from repro.sim import engine_cache_stats, reset_engine_cache


@pytest.fixture(scope="session", autouse=True)
def _engine_cache_clean_at_session_start():
    """Importing test modules (or plugins) must not populate the cache —
    a dirty cache at collection time would mean import-time engine builds."""
    stats = engine_cache_stats()
    assert stats == {"hits": 0, "misses": 0, "size": 0}, (
        f"engine cache dirty at session start: {stats}"
    )
    yield


@pytest.fixture(autouse=True)
def _fresh_engine_cache():
    """Order-independence: every test sees an empty engine cache."""
    reset_engine_cache()
    yield
