"""AirComp signal-chain tests: Lemma 1, Eq. 5/8/15/16."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aircomp


def _setup(key, n=8, dim=64):
    k1, k2, k3 = jax.random.split(key, 3)
    g = jax.random.normal(k1, (n, dim)) * jnp.arange(1, n + 1)[:, None] * 0.1
    h = (jax.random.normal(k2, (n,)) + 1j * jax.random.normal(k3, (n,))) / jnp.sqrt(2)
    h = h * jnp.linspace(0.5, 2.0, n)  # varied channel quality
    rho = jnp.linspace(0.05, 0.2, n)
    mask = (jnp.arange(n) % 2 == 0).astype(jnp.float32)
    return g, h, rho, mask


def test_lemma1_power_constraint():
    """|b_i|^2 <= P must hold with equality for the argmin device."""
    g, h, rho, mask = _setup(jax.random.PRNGKey(0))
    P = 1.0
    a = aircomp.denoise_scalar(rho, jnp.abs(h), mask, P)
    ok = aircomp.power_check(rho, h, a, P)
    assert bool(jnp.all(ok[mask > 0]))
    b = aircomp.transmit_scalars(rho, h, a)
    powers = jnp.where(mask > 0, jnp.abs(b) ** 2, 0.0)
    np.testing.assert_allclose(float(jnp.max(powers)), P, rtol=1e-5)


def test_normalization_unit_stats():
    """Eq. 5 with the device's own stats gives zero-mean unit-variance symbols."""
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 4096)) * 3.0 + 1.5
    stats = aircomp.local_stats(g)
    s = jax.vmap(aircomp.normalize)(g, stats.mean, stats.var)
    np.testing.assert_allclose(jnp.mean(s, axis=1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.var(s, axis=1), 1.0, rtol=1e-4)


def test_physical_path_matches_eq16_up_to_mean_term():
    """The full Eq. 5→8 physical chain equals the Lemma-1 simplified Eq. 16
    estimator up to the documented M_g·(1−Σρ_i) mean term (DESIGN.md note:
    Eq. 9 in the paper implicitly assumes Σ_{i∈S} h_i b_i / a = 1)."""
    g, h, rho, mask = _setup(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(7)
    y_phys, e1 = aircomp.aircomp_aggregate(
        g, rho, h, mask, key, 1.0, 1e-6, simulate_physical=True
    )
    y_eq16, e2 = aircomp.aircomp_aggregate(
        g, rho, h, mask, key, 1.0, 1e-6, simulate_physical=False
    )
    stats = aircomp.local_stats(g)
    m_g, _ = aircomp.global_stats(stats, rho, mask)
    mean_term = m_g * (1.0 - jnp.sum(mask * rho))
    np.testing.assert_allclose(y_phys, y_eq16 + mean_term, rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(e1, e2)


def test_distortion_closed_form_matches_monte_carlo():
    """Eq. 15: E||ŷ − y||² over the noise = D σ_z² V_g / P · max ρ²/|h|²."""
    g, h, rho, mask = _setup(jax.random.PRNGKey(2), n=6, dim=32)
    P, s2 = 1.0, 1e-3
    target = jnp.sum((mask * rho)[:, None] * g, axis=0)

    def one(key):
        y, _ = aircomp.aircomp_aggregate(
            g, rho, h, mask, key, P, s2, simulate_physical=False
        )
        return jnp.sum((y - target) ** 2)

    keys = jax.random.split(jax.random.PRNGKey(3), 4000)
    emp = jnp.mean(jax.vmap(one)(keys))
    stats = aircomp.local_stats(g)
    _, v_g = aircomp.global_stats(stats, rho, mask)
    closed = aircomp.distortion_closed_form(
        v_g, rho, jnp.abs(h), mask, g.shape[-1], P, s2
    )
    np.testing.assert_allclose(emp, closed, rtol=0.08)


def test_zero_noise_recovers_exact_weighted_sum():
    g, h, rho, mask = _setup(jax.random.PRNGKey(4))
    y, e = aircomp.aircomp_aggregate(
        g, rho, h, mask, jax.random.PRNGKey(0), 1.0, 0.0, simulate_physical=False
    )
    target = jnp.sum((mask * rho)[:, None] * g, axis=0)
    np.testing.assert_allclose(y, target, rtol=1e-5, atol=1e-6)
    assert float(e) == 0.0

    # the physical chain at zero noise recovers the sum + the mean term
    y_p, _ = aircomp.aircomp_aggregate(
        g, rho, h, mask, jax.random.PRNGKey(0), 1.0, 0.0, simulate_physical=True
    )
    stats = aircomp.local_stats(g)
    m_g, _ = aircomp.global_stats(stats, rho, mask)
    mean_term = m_g * (1.0 - jnp.sum(mask * rho))
    np.testing.assert_allclose(y_p, target + mean_term, rtol=2e-4, atol=1e-6)


def test_denoise_scalar_over_scheduled_set_only():
    rho = jnp.array([0.1, 0.1, 0.1])
    h_abs = jnp.array([1e-6, 1.0, 2.0])  # device 0 has a terrible channel
    mask = jnp.array([0.0, 1.0, 1.0])    # ... but is not scheduled
    a = aircomp.denoise_scalar(rho, h_abs, mask, 1.0)
    np.testing.assert_allclose(float(a), 1.0 / 0.1, rtol=1e-6)
