"""repro.sim subsystem tests: engine↔run_pofl trajectory equivalence,
channel-scenario statistics, Dirichlet partition, lattice records, and the
trial-batched fused kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig, make_round_step
from repro.core.channel import ChannelConfig, ChannelState
from repro.data import (
    make_classification_dataset,
    partition_dirichlet,
    partition_noniid_shards,
)
from repro.kernels.aircomp import aircomp_fused_batch, aircomp_fused_batch_ref
from repro.sim import LatticeSpec, SimEngine, make_channel_process, run_lattice


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 1200, key)
    data = partition_noniid_shards(x, y, n_devices=12)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def ev(p):
        logits = x[:400] @ p["w"] + p["b"]
        return _loss_fn(p, x[:400], y[:400]), jnp.mean(jnp.argmax(logits, -1) == y[:400])

    return data, params0, ev


# --------------------------------------------------------------------------
# engine ↔ run_pofl equivalence (acceptance criterion: ≤1e-5 on static fading)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["pofl", "deterministic"])
def test_engine_matches_legacy_round_loop(setup, policy):
    """The scanned engine must reproduce the historical per-round-jit Python
    loop (the seed repo's run_pofl) for identical seeds on static fading."""
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy=policy, seed=3)
    n_rounds = 8

    # legacy loop: per-round jit, key chain advanced in Python
    key = jax.random.PRNGKey(cfg.seed)
    k_chan_init, key = jax.random.split(key)
    channel = ChannelState.create(
        ChannelConfig(
            n_devices=12, tx_power=cfg.tx_power, noise_power=cfg.noise_power
        ),
        k_chan_init,
    )
    step = make_round_step(_loss_fn, data, channel, cfg)
    params = params0
    e_coms = []
    for t in range(n_rounds):
        key, k_round = jax.random.split(key)
        params, m = step(params, k_round, jnp.asarray(t, jnp.float32))
        e_coms.append(float(m.e_com))

    # scanned engine (via the run_pofl wrapper)
    engine = SimEngine(_loss_fn, data, cfg)
    params_sim, hist = engine.run_with_history(params0, n_rounds, eval_fn=ev)
    np.testing.assert_allclose(
        np.asarray(params_sim["w"]), np.asarray(params["w"]), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(hist.e_com), e_coms, rtol=1e-5)
    assert hist.test_round[-1] == n_rounds - 1


def test_run_with_history_matches_plain_chunks(setup):
    """Eval chunking must not perturb the trajectory: same params with and
    without an eval_fn."""
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, seed=11)
    engine = SimEngine(_loss_fn, data, cfg)
    p_eval, _ = engine.run_with_history(params0, 7, eval_fn=ev, eval_every=3)
    p_plain, hist = engine.run_with_history(params0, 7, eval_fn=None)
    np.testing.assert_array_equal(np.asarray(p_eval["w"]), np.asarray(p_plain["w"]))
    assert len(hist.e_com) == 7 and hist.test_round == []


# --------------------------------------------------------------------------
# channel scenarios
# --------------------------------------------------------------------------


def _rollout(proc, key, n_rounds):
    state = proc.init(jax.random.PRNGKey(0))

    def body(st, k):
        st, h, avail = proc.step(st, k)
        return st, (h, avail)

    _, (hs, avails) = jax.lax.scan(body, state, jax.random.split(key, n_rounds))
    return hs, avails  # each (n_rounds, n_devices)


def test_gauss_markov_stationary_moments():
    """h_t must stay CN(0, g_i): E[h]≈0, E[|h|²]≈g_i, and lag-1 autocorr≈ρ."""
    cfg = ChannelConfig(n_devices=6)
    proc = make_channel_process("gauss_markov", cfg, corr=0.8)
    gains = proc.init(jax.random.PRNGKey(0))[0]
    hs, avails = _rollout(proc, jax.random.PRNGKey(1), 4000)
    assert np.asarray(avails).all()  # gauss_markov never drops devices

    emp_power = jnp.mean(jnp.abs(hs) ** 2, axis=0)
    np.testing.assert_allclose(np.asarray(emp_power), np.asarray(gains), rtol=0.15)
    emp_mean = np.abs(np.asarray(jnp.mean(hs, axis=0)))
    assert emp_mean.max() < 0.15 * float(jnp.sqrt(gains.max()))

    lag1 = jnp.mean(hs[1:] * jnp.conj(hs[:-1]), axis=0)
    rho_hat = np.asarray(jnp.real(lag1) / emp_power)
    np.testing.assert_allclose(rho_hat, 0.8, atol=0.1)


def test_static_rayleigh_matches_channelstate():
    """The registry's static scenario is bit-identical to core ChannelState."""
    cfg = ChannelConfig(n_devices=8)
    proc = make_channel_process("static_rayleigh", cfg)
    state = proc.init(jax.random.PRNGKey(5))
    legacy = ChannelState.create(cfg, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(state[0]), np.asarray(legacy.gains))
    _, h, avail = proc.step(state, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(
        np.asarray(h), np.asarray(legacy.sample(jax.random.PRNGKey(9)))
    )
    np.testing.assert_array_equal(np.asarray(avail), 1.0)


def test_mobility_distances_stay_in_cell():
    cfg = ChannelConfig(n_devices=5, d_min=10.0, d_max=50.0)
    proc = make_channel_process("mobility", cfg, speed=30.0)
    state = proc.init(jax.random.PRNGKey(0))
    for i in range(50):
        state, _, _ = proc.step(state, jax.random.fold_in(jax.random.PRNGKey(1), i))
        d = np.asarray(state[0])
        assert (d >= cfg.d_min - 1e-4).all() and (d <= cfg.d_max + 1e-4).all()


def test_dropout_marks_devices_unavailable():
    cfg = ChannelConfig(n_devices=32)
    proc = make_channel_process("dropout", cfg, p_drop=0.3)
    base = make_channel_process("static_rayleigh", cfg)
    st_d = proc.init(jax.random.PRNGKey(0))
    st_b = base.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(7)
    _, h_d, avail = proc.step(st_d, k)
    # the base fading trajectory is untouched (k_base = split(k)[0])
    k_base, _ = jax.random.split(k)
    _, h_b, _ = base.step(st_b, k_base)
    np.testing.assert_array_equal(np.asarray(h_d), np.asarray(h_b))
    avail = np.asarray(avail)
    assert set(np.unique(avail)) <= {0.0, 1.0}
    assert 0 < (avail == 0).sum() < 32  # some but not all dropped at p=0.3

    _, avails = _rollout(proc, jax.random.PRNGKey(3), 2000)
    drop_rate = 1.0 - float(np.mean(np.asarray(avails)))
    np.testing.assert_allclose(drop_rate, 0.3, atol=0.03)


def test_sampler_clamps_when_fewer_selectable_than_s():
    """Zero-prob (unavailable) devices are never drafted and never weighted:
    with 3 selectable devices and |S|=4 the realized schedule is exactly the
    3 selectable ones, surplus draws are -1 sentinels, and the Eq. 37
    weights stay finite and zero off the selectable set."""
    from repro.core import scheduling

    probs = jnp.array([0.5, 0.3, 0.2] + [0.0] * 9)
    data_frac = jnp.full((12,), 1.0 / 12)
    for seed in range(5):
        sched = scheduling.sample_without_replacement(
            jax.random.PRNGKey(seed), probs, 4
        )
        mask = np.asarray(sched.mask)
        np.testing.assert_array_equal(mask[:3], 1.0)
        np.testing.assert_array_equal(mask[3:], 0.0)
        assert (np.asarray(sched.indices) == -1).sum() == 1
        rho = np.asarray(
            scheduling.aggregation_weights(sched, probs, data_frac, 4)
        )
        assert np.isfinite(rho).all()
        np.testing.assert_array_equal(rho[3:], 0.0)
        assert (rho[:3] > 0).all()


def test_dropout_empty_rounds_finite_on_physical_path(setup):
    """Rounds where every device drops must not NaN the Eq. 5→8 physical
    chain (a=inf, rho=0 would give 0·inf transmit scalars without the
    mask-before-multiply guard in aircomp_aggregate)."""
    data, params0, _ = setup
    cfg = POFLConfig(
        n_devices=12, n_scheduled=3, policy="pofl", seed=0,
        simulate_physical=True,
    )
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="dropout",
        scenario_params={"p_drop": 0.85},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(50, dtype=jnp.int32), jnp.zeros(50, bool)
        )
    )(state)
    assert (np.asarray(recs.n_scheduled) == 0).any()  # empty rounds occurred
    assert np.isfinite(np.asarray(final.params["w"])).all()
    assert np.isfinite(np.asarray(recs.grad_norm)).all()


def test_dropout_rounds_stay_finite(setup):
    """Even in rounds where dropout leaves fewer than |S| devices available,
    the engine's trajectory and metrics stay finite (|S| clamps)."""
    data, params0, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="dropout",
        # p_drop=0.75: P(<4 of 12 available) ≈ 0.65 per round, so the
        # clamping path definitely fires within 40 rounds
        scenario_params={"p_drop": 0.75},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(40, dtype=jnp.int32), jnp.zeros(40, bool)
        )
    )(state)
    n_sched = np.asarray(recs.n_scheduled)
    assert np.isfinite(np.asarray(recs.e_com)).all()
    assert np.isfinite(np.asarray(recs.e_var)).all()
    assert np.isfinite(np.asarray(jax.tree.leaves(final.params)[0])).all()
    assert (n_sched <= 4).all() and n_sched.min() < 4  # clamping observed


# --------------------------------------------------------------------------
# dirichlet partition
# --------------------------------------------------------------------------


def test_dirichlet_partition_shapes_and_skew():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 2000, key)
    n_dev = 10
    skewed = partition_dirichlet(x, y, n_dev, beta=0.1, seed=0)
    near_iid = partition_dirichlet(x, y, n_dev, beta=1000.0, seed=0)

    per = 2000 // n_dev
    assert skewed.features.shape == (n_dev, per, 784)
    assert skewed.labels.shape == (n_dev, per)

    def mean_top_class_frac(dd):
        fracs = []
        for d in range(n_dev):
            _, counts = np.unique(np.asarray(dd.labels[d]), return_counts=True)
            fracs.append(counts.max() / counts.sum())
        return float(np.mean(fracs))

    # β→0 concentrates mass on few classes; β→∞ recovers ~uniform (10
    # classes → top frac ≈ 0.1–0.2). The equal-size constraint dilutes the
    # skew for late devices (class pools run dry), so ~0.4 is the realistic
    # concentrated value, still far from uniform.
    assert mean_top_class_frac(skewed) > 0.35
    assert mean_top_class_frac(near_iid) < 0.25
    assert mean_top_class_frac(skewed) > mean_top_class_frac(near_iid) + 0.15
    # no sample is duplicated across devices: the per-class totals over all
    # shards can then never exceed the global per-class counts (and with
    # M divisible by N they must match exactly)
    global_classes, global_counts = np.unique(np.asarray(y), return_counts=True)
    part_classes, part_counts = np.unique(
        np.asarray(skewed.labels).ravel(), return_counts=True
    )
    np.testing.assert_array_equal(part_classes, global_classes)
    np.testing.assert_array_equal(part_counts, global_counts)
    # ...and the feature rows themselves are all distinct (continuous
    # features are unique w.p. 1, so any duplicate row = a reused sample)
    flat = np.asarray(skewed.features).reshape(n_dev * per, -1)
    assert np.unique(flat, axis=0).shape[0] == n_dev * per


# --------------------------------------------------------------------------
# lattice records
# --------------------------------------------------------------------------


def test_lattice_record_shapes_and_axes(setup):
    data, params0, ev = setup
    spec = LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        alphas=(0.1, 1.0),
        seeds=(0, 1000, 2000),
        n_rounds=6,
        eval_every=2,
    )
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=12, n_scheduled=4),
        eval_fn=ev,
    )
    assert recs.e_com.shape == (2, 2, 2, 3, 6)
    np.testing.assert_array_equal(recs.eval_rounds, [0, 2, 4, 5])
    assert recs.acc.shape == (2, 2, 2, 3, 4)
    assert np.isfinite(recs.e_com).all() and np.isfinite(recs.acc).all()
    assert (recs.n_scheduled >= 1).all()

    c = recs.cell(policy="pofl", noise_power=1e-9, alpha=1.0)
    assert c["acc"].shape == (3, 4)
    with pytest.raises(ValueError):
        recs.cell(nonsense=3)


def test_lattice_single_cell_matches_run_pofl(setup):
    """A 1-cell lattice is the engine run end-to-end: accuracies must match
    run_pofl (which shares the engine) exactly in eval rounds and closely in
    values (eval inside scan vs on host)."""
    from repro.core import run_pofl

    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    spec = LatticeSpec(policies=("pofl",), seeds=(0,), n_rounds=6, eval_every=2)
    recs = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=jax.jit(ev)
    )
    _, hist = run_pofl(_loss_fn, params0, data, cfg, 6, eval_fn=jax.jit(ev), eval_every=2)
    np.testing.assert_array_equal(recs.eval_rounds, hist.test_round)
    np.testing.assert_allclose(
        recs.acc[0, 0, 0, 0], hist.test_acc, rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        recs.e_com[0, 0, 0, 0], hist.e_com, rtol=1e-5
    )


def test_lattice_gauss_markov_runs(setup):
    data, params0, _ = setup
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1000), n_rounds=4)
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=12, n_scheduled=4),
        scenario="gauss_markov", scenario_params={"corr": 0.95},
    )
    assert recs.e_com.shape == (1, 1, 1, 2, 4)
    assert np.isfinite(recs.e_com).all()
    assert recs.acc.shape[-1] == 0  # no eval_fn → empty eval axis


# --------------------------------------------------------------------------
# trial-batched fused kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("bt,n,d", [(1, 4, 512), (3, 12, 700), (5, 30, 1024)])
def test_aircomp_fused_batch_matches_ref(bt, n, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    g = jax.random.normal(ks[0], (bt, n, d))
    coeff = jax.random.uniform(ks[1], (bt, n)) * (
        jax.random.uniform(ks[2], (bt, n)) > 0.3
    )
    z = jax.random.normal(ks[3], (bt, d))
    m_g = 0.1 * jax.random.normal(ks[4], (bt,))
    v_g = jax.random.uniform(ks[5], (bt,)) + 0.2
    a = jnp.linspace(1.0, 3.0, bt)

    got = aircomp_fused_batch(g, coeff, m_g, v_g, a, z, interpret=True)
    want = aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
