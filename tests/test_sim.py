"""repro.sim subsystem tests: engine↔run_pofl trajectory equivalence,
engine caching / retrace guards, aggregation-backend parity, heterogeneous
(Dirichlet-sized) shards, channel-scenario statistics, Dirichlet partition,
lattice records, and the trial-batched fused kernel."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceData, POFLConfig, make_round_step, run_pofl
from repro.core.channel import ChannelConfig, ChannelState
from repro.data import (
    dirichlet_sizes,
    make_classification_dataset,
    partition_dirichlet,
    partition_dirichlet_mixed,
    partition_dirichlet_sized,
    partition_noniid_shards,
)
from repro.kernels.aircomp import aircomp_fused_batch, aircomp_fused_batch_ref
from repro.sim import (
    LatticeSpec,
    SimEngine,
    cached_engine,
    engine_cache_stats,
    make_channel_process,
    run_lattice,
)


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 1200, key)
    data = partition_noniid_shards(x, y, n_devices=12)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def ev(p):
        logits = x[:400] @ p["w"] + p["b"]
        return _loss_fn(p, x[:400], y[:400]), jnp.mean(jnp.argmax(logits, -1) == y[:400])

    return data, params0, ev


# --------------------------------------------------------------------------
# engine ↔ run_pofl equivalence (acceptance criterion: ≤1e-5 on static fading)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["pofl", "deterministic"])
def test_engine_matches_legacy_round_loop(setup, policy):
    """The scanned engine must reproduce the historical per-round-jit Python
    loop (the seed repo's run_pofl) for identical seeds on static fading."""
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy=policy, seed=3)
    n_rounds = 8

    # legacy loop: per-round jit, key chain advanced in Python
    key = jax.random.PRNGKey(cfg.seed)
    k_chan_init, key = jax.random.split(key)
    channel = ChannelState.create(
        ChannelConfig(
            n_devices=12, tx_power=cfg.tx_power, noise_power=cfg.noise_power
        ),
        k_chan_init,
    )
    step = make_round_step(_loss_fn, data, channel, cfg)
    params = params0
    e_coms = []
    for t in range(n_rounds):
        key, k_round = jax.random.split(key)
        params, m = step(params, k_round, jnp.asarray(t, jnp.float32))
        e_coms.append(float(m.e_com))

    # scanned engine (via the run_pofl wrapper)
    engine = SimEngine(_loss_fn, data, cfg)
    params_sim, hist = engine.run_with_history(params0, n_rounds, eval_fn=ev)
    np.testing.assert_allclose(
        np.asarray(params_sim["w"]), np.asarray(params["w"]), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(hist.e_com), e_coms, rtol=1e-5)
    assert hist.test_round[-1] == n_rounds - 1


def test_run_with_history_matches_plain_chunks(setup):
    """Eval chunking must not perturb the trajectory: same params with and
    without an eval_fn."""
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, seed=11)
    engine = SimEngine(_loss_fn, data, cfg)
    p_eval, _ = engine.run_with_history(params0, 7, eval_fn=ev, eval_every=3)
    p_plain, hist = engine.run_with_history(params0, 7, eval_fn=None)
    np.testing.assert_array_equal(np.asarray(p_eval["w"]), np.asarray(p_plain["w"]))
    assert len(hist.e_com) == 7 and hist.test_round == []


# --------------------------------------------------------------------------
# channel scenarios
# --------------------------------------------------------------------------


def _rollout(proc, key, n_rounds):
    state = proc.init(jax.random.PRNGKey(0))

    def body(st, k):
        st, h, avail = proc.step(st, k)
        return st, (h, avail)

    _, (hs, avails) = jax.lax.scan(body, state, jax.random.split(key, n_rounds))
    return hs, avails  # each (n_rounds, n_devices)


def test_gauss_markov_stationary_moments():
    """h_t must stay CN(0, g_i): E[h]≈0, E[|h|²]≈g_i, and lag-1 autocorr≈ρ."""
    cfg = ChannelConfig(n_devices=6)
    proc = make_channel_process("gauss_markov", cfg, corr=0.8)
    gains = proc.init(jax.random.PRNGKey(0))[0]
    hs, avails = _rollout(proc, jax.random.PRNGKey(1), 4000)
    assert np.asarray(avails).all()  # gauss_markov never drops devices

    emp_power = jnp.mean(jnp.abs(hs) ** 2, axis=0)
    np.testing.assert_allclose(np.asarray(emp_power), np.asarray(gains), rtol=0.15)
    emp_mean = np.abs(np.asarray(jnp.mean(hs, axis=0)))
    assert emp_mean.max() < 0.15 * float(jnp.sqrt(gains.max()))

    lag1 = jnp.mean(hs[1:] * jnp.conj(hs[:-1]), axis=0)
    rho_hat = np.asarray(jnp.real(lag1) / emp_power)
    np.testing.assert_allclose(rho_hat, 0.8, atol=0.1)


def test_static_rayleigh_matches_channelstate():
    """The registry's static scenario is bit-identical to core ChannelState."""
    cfg = ChannelConfig(n_devices=8)
    proc = make_channel_process("static_rayleigh", cfg)
    state = proc.init(jax.random.PRNGKey(5))
    legacy = ChannelState.create(cfg, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(state[0]), np.asarray(legacy.gains))
    _, h, avail = proc.step(state, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(
        np.asarray(h), np.asarray(legacy.sample(jax.random.PRNGKey(9)))
    )
    np.testing.assert_array_equal(np.asarray(avail), 1.0)


def test_mobility_distances_stay_in_cell():
    cfg = ChannelConfig(n_devices=5, d_min=10.0, d_max=50.0)
    proc = make_channel_process("mobility", cfg, speed=30.0)
    state = proc.init(jax.random.PRNGKey(0))
    for i in range(50):
        state, _, _ = proc.step(state, jax.random.fold_in(jax.random.PRNGKey(1), i))
        d = np.asarray(state[0])
        assert (d >= cfg.d_min - 1e-4).all() and (d <= cfg.d_max + 1e-4).all()


def test_dropout_marks_devices_unavailable():
    cfg = ChannelConfig(n_devices=32)
    proc = make_channel_process("dropout", cfg, p_drop=0.3)
    base = make_channel_process("static_rayleigh", cfg)
    st_d = proc.init(jax.random.PRNGKey(0))
    st_b = base.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(7)
    _, h_d, avail = proc.step(st_d, k)
    # the base fading trajectory is untouched (k_base = split(k)[0])
    k_base, _ = jax.random.split(k)
    _, h_b, _ = base.step(st_b, k_base)
    np.testing.assert_array_equal(np.asarray(h_d), np.asarray(h_b))
    avail = np.asarray(avail)
    assert set(np.unique(avail)) <= {0.0, 1.0}
    assert 0 < (avail == 0).sum() < 32  # some but not all dropped at p=0.3

    _, avails = _rollout(proc, jax.random.PRNGKey(3), 2000)
    drop_rate = 1.0 - float(np.mean(np.asarray(avails)))
    np.testing.assert_allclose(drop_rate, 0.3, atol=0.03)


def test_sampler_clamps_when_fewer_selectable_than_s():
    """Zero-prob (unavailable) devices are never drafted and never weighted:
    with 3 selectable devices and |S|=4 the realized schedule is exactly the
    3 selectable ones, surplus draws are -1 sentinels, and the Eq. 37
    weights stay finite and zero off the selectable set."""
    from repro.core import scheduling

    probs = jnp.array([0.5, 0.3, 0.2] + [0.0] * 9)
    data_frac = jnp.full((12,), 1.0 / 12)
    for seed in range(5):
        sched = scheduling.sample_without_replacement(
            jax.random.PRNGKey(seed), probs, 4
        )
        mask = np.asarray(sched.mask)
        np.testing.assert_array_equal(mask[:3], 1.0)
        np.testing.assert_array_equal(mask[3:], 0.0)
        assert (np.asarray(sched.indices) == -1).sum() == 1
        rho = np.asarray(
            scheduling.aggregation_weights(sched, probs, data_frac, 4)
        )
        assert np.isfinite(rho).all()
        np.testing.assert_array_equal(rho[3:], 0.0)
        assert (rho[:3] > 0).all()


def test_dropout_empty_rounds_finite_on_physical_path(setup):
    """Rounds where every device drops must not NaN the Eq. 5→8 physical
    chain (a=inf, rho=0 would give 0·inf transmit scalars without the
    mask-before-multiply guard in aircomp_aggregate)."""
    data, params0, _ = setup
    cfg = POFLConfig(
        n_devices=12, n_scheduled=3, policy="pofl", seed=0,
        simulate_physical=True,
    )
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="dropout",
        scenario_params={"p_drop": 0.85},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(50, dtype=jnp.int32), jnp.zeros(50, bool)
        )
    )(state)
    assert (np.asarray(recs.n_scheduled) == 0).any()  # empty rounds occurred
    assert np.isfinite(np.asarray(final.params["w"])).all()
    assert np.isfinite(np.asarray(recs.grad_norm)).all()


def test_dropout_rounds_stay_finite(setup):
    """Even in rounds where dropout leaves fewer than |S| devices available,
    the engine's trajectory and metrics stay finite (|S| clamps)."""
    data, params0, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="dropout",
        # p_drop=0.75: P(<4 of 12 available) ≈ 0.65 per round, so the
        # clamping path definitely fires within 40 rounds
        scenario_params={"p_drop": 0.75},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(40, dtype=jnp.int32), jnp.zeros(40, bool)
        )
    )(state)
    n_sched = np.asarray(recs.n_scheduled)
    assert np.isfinite(np.asarray(recs.e_com)).all()
    assert np.isfinite(np.asarray(recs.e_var)).all()
    assert np.isfinite(np.asarray(jax.tree.leaves(final.params)[0])).all()
    assert (n_sched <= 4).all() and n_sched.min() < 4  # clamping observed


# --------------------------------------------------------------------------
# dirichlet partition
# --------------------------------------------------------------------------


def test_dirichlet_partition_shapes_and_skew():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 2000, key)
    n_dev = 10
    skewed = partition_dirichlet(x, y, n_dev, beta=0.1, seed=0)
    near_iid = partition_dirichlet(x, y, n_dev, beta=1000.0, seed=0)

    per = 2000 // n_dev
    assert skewed.features.shape == (n_dev, per, 784)
    assert skewed.labels.shape == (n_dev, per)

    def mean_top_class_frac(dd):
        fracs = []
        for d in range(n_dev):
            _, counts = np.unique(np.asarray(dd.labels[d]), return_counts=True)
            fracs.append(counts.max() / counts.sum())
        return float(np.mean(fracs))

    # β→0 concentrates mass on few classes; β→∞ recovers ~uniform (10
    # classes → top frac ≈ 0.1–0.2). The equal-size constraint dilutes the
    # skew for late devices (class pools run dry), so ~0.4 is the realistic
    # concentrated value, still far from uniform.
    assert mean_top_class_frac(skewed) > 0.35
    assert mean_top_class_frac(near_iid) < 0.25
    assert mean_top_class_frac(skewed) > mean_top_class_frac(near_iid) + 0.15
    # no sample is duplicated across devices: the per-class totals over all
    # shards can then never exceed the global per-class counts (and with
    # M divisible by N they must match exactly)
    global_classes, global_counts = np.unique(np.asarray(y), return_counts=True)
    part_classes, part_counts = np.unique(
        np.asarray(skewed.labels).ravel(), return_counts=True
    )
    np.testing.assert_array_equal(part_classes, global_classes)
    np.testing.assert_array_equal(part_counts, global_counts)
    # ...and the feature rows themselves are all distinct (continuous
    # features are unique w.p. 1, so any duplicate row = a reused sample)
    flat = np.asarray(skewed.features).reshape(n_dev * per, -1)
    assert np.unique(flat, axis=0).shape[0] == n_dev * per


# --------------------------------------------------------------------------
# lattice records
# --------------------------------------------------------------------------


def test_lattice_record_shapes_and_axes(setup):
    data, params0, ev = setup
    spec = LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        alphas=(0.1, 1.0),
        seeds=(0, 1000, 2000),
        n_rounds=6,
        eval_every=2,
    )
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=12, n_scheduled=4),
        eval_fn=ev,
    )
    assert recs.e_com.shape == (1, 2, 2, 2, 3, 6)  # leading algorithm axis
    np.testing.assert_array_equal(recs.eval_rounds, [0, 2, 4, 5])
    assert recs.acc.shape == (1, 2, 2, 2, 3, 4)
    assert np.isfinite(recs.e_com).all() and np.isfinite(recs.acc).all()
    assert (recs.n_scheduled >= 1).all()

    c = recs.cell(policy="pofl", noise_power=1e-9, alpha=1.0)
    assert c["acc"].shape == (1, 3, 4)  # un-named algorithm axis stays (size 1)
    with pytest.raises(ValueError):
        recs.cell(nonsense=3)


def test_lattice_single_cell_matches_run_pofl(setup):
    """A 1-cell lattice is the engine run end-to-end: accuracies must match
    run_pofl (which shares the engine) exactly in eval rounds and closely in
    values (eval inside scan vs on host)."""
    from repro.core import run_pofl

    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    spec = LatticeSpec(policies=("pofl",), seeds=(0,), n_rounds=6, eval_every=2)
    recs = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=jax.jit(ev)
    )
    _, hist = run_pofl(_loss_fn, params0, data, cfg, 6, eval_fn=jax.jit(ev), eval_every=2)
    np.testing.assert_array_equal(recs.eval_rounds, hist.test_round)
    np.testing.assert_allclose(
        recs.acc[0, 0, 0, 0, 0], hist.test_acc, rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        recs.e_com[0, 0, 0, 0, 0], hist.e_com, rtol=1e-5
    )


def test_lattice_gauss_markov_runs(setup):
    data, params0, _ = setup
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1000), n_rounds=4)
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=12, n_scheduled=4),
        scenario="gauss_markov", scenario_params={"corr": 0.95},
    )
    assert recs.e_com.shape == (1, 1, 1, 1, 2, 4)
    assert np.isfinite(recs.e_com).all()
    assert recs.acc.shape[-1] == 0  # no eval_fn → empty eval axis


# --------------------------------------------------------------------------
# engine cache + retrace guard
# --------------------------------------------------------------------------


def test_engine_cache_no_retrace_on_repeat_call(setup):
    """A repeat ``run_pofl`` with the same config (any seed) must reuse the
    cached engine with ZERO new scan traces — the PR-2 cold-call fix and the
    CI retrace guard (``-k no_retrace``)."""
    data, params0, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=7)
    p1, _ = run_pofl(_loss_fn, params0, data, cfg, 6)

    engine = cached_engine(_loss_fn, data, cfg)  # must be a hit, not a build
    traces_after_first = engine.n_traces
    assert traces_after_first >= 1

    stats0 = engine_cache_stats()
    p2, _ = run_pofl(_loss_fn, params0, data, cfg, 6)
    # same engine object, zero new traces, pure cache hit
    assert cached_engine(_loss_fn, data, cfg) is engine
    assert engine.n_traces == traces_after_first
    assert engine_cache_stats()["hits"] > stats0["hits"]
    assert engine_cache_stats()["misses"] == stats0["misses"]
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))

    # a different seed shares the engine (cfg-minus-seed keying)…
    run_pofl(_loss_fn, params0, data, dataclasses.replace(cfg, seed=123), 6)
    assert engine.n_traces == traces_after_first
    # …a different backend does not
    other = cached_engine(
        _loss_fn, data, dataclasses.replace(cfg, backend="pallas_fused")
    )
    assert other is not engine


def test_static_length_scan_pads_without_perturbing(setup):
    """n_rounds that don't divide evenly into eval segments exercise the
    active-mask padding: history lengths and trajectories must match an
    unpadded single-segment run of the same rounds."""
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, seed=5)
    engine = SimEngine(_loss_fn, data, cfg)
    # segments [1, 3, 3] (L=3, first padded) vs one unpadded 7-round segment
    p_eval, hist = engine.run_with_history(params0, 7, eval_fn=ev, eval_every=3)
    p_plain, hist_plain = engine.run_with_history(params0, 7, eval_fn=None)
    np.testing.assert_array_equal(np.asarray(p_eval["w"]), np.asarray(p_plain["w"]))
    assert len(hist.e_com) == 7 == len(hist_plain.e_com)
    np.testing.assert_allclose(hist.e_com, hist_plain.e_com, rtol=1e-6)


# --------------------------------------------------------------------------
# aggregation backends
# --------------------------------------------------------------------------


def test_backend_parity_on_small_lattice(setup):
    """pallas_fused (fused Eq. 5→8, jnp oracle on CPU) must track the exact
    jnp physical path round-for-round on a small lattice."""
    data, params0, ev = setup
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1000), n_rounds=5)
    base = POFLConfig(
        n_devices=12, n_scheduled=4, simulate_physical=True, backend="jnp"
    )
    recs_jnp = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=base, eval_fn=ev
    )
    recs_fused = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=dataclasses.replace(base, backend="pallas_fused"), eval_fn=ev,
    )
    np.testing.assert_allclose(
        recs_fused.grad_norm, recs_jnp.grad_norm, rtol=1e-4, atol=1e-6
    )
    np.testing.assert_allclose(recs_fused.e_com, recs_jnp.e_com, rtol=1e-5)
    np.testing.assert_allclose(recs_fused.acc, recs_jnp.acc, rtol=1e-4, atol=1e-4)


def test_backend_interpret_mode_parity():
    """The CPU interpreter-mode path of the fused backend (the round body's
    actual Pallas kernel, interpreted) matches the jnp reference stage."""
    from repro.core import aggregation_stage

    cfg = POFLConfig(
        n_devices=6, n_scheduled=3, backend="pallas_fused",
        simulate_physical=True,
    )
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    g = jax.random.normal(ks[0], (6, 700))
    h = (jax.random.normal(ks[1], (6,)) + 1j * jax.random.normal(ks[2], (6,))).astype(
        jnp.complex64
    )
    rho = jnp.array([0.3, 0.5, 0.2, 0.0, 0.0, 0.0])
    mask = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    y_interp, e_interp = aggregation_stage(
        cfg, g, rho, h, mask, ks[3], 1e-8, use_pallas="interpret"
    )
    y_ref, e_ref = aggregation_stage(
        cfg, g, rho, h, mask, ks[3], 1e-8, use_pallas=False
    )
    cfg_jnp = dataclasses.replace(cfg, backend="jnp")
    y_jnp, e_jnp = aggregation_stage(cfg_jnp, g, rho, h, mask, ks[3], 1e-8)
    np.testing.assert_allclose(np.asarray(y_interp), np.asarray(y_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_interp), np.asarray(y_jnp), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(e_interp), float(e_jnp), rtol=1e-5)
    np.testing.assert_allclose(float(e_ref), float(e_jnp), rtol=1e-5)


def test_unknown_backend_rejected(setup):
    data, params0, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, backend="nonsense")
    with pytest.raises(ValueError):
        run_pofl(_loss_fn, params0, data, cfg, 1)


def test_interpret_env_var_dispatch_and_cache_keying(setup, monkeypatch):
    """REPRO_PALLAS_INTERPRET flips the 'auto' dispatch to interpret mode at
    trace time, and cached_engine keys on it so a flipped var can never
    replay a stale-mode trace."""
    from repro.kernels.aircomp.ops import _resolve

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert _resolve("auto") in (True, False)  # plain hardware dispatch
    assert _resolve(False) is False and _resolve("interpret") == "interpret"
    data, _, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, backend="pallas_fused")
    eng_plain = cached_engine(_loss_fn, data, cfg)

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert _resolve("auto") == "interpret"
    assert cached_engine(_loss_fn, data, cfg) is not eng_plain


def test_cached_engine_accepts_array_scenario_params(setup):
    """Anything SimEngine accepts as a scenario param must also key the
    cache (arrays/lists freeze to tuples instead of raising TypeError)."""
    data, _, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4)
    params = {"corr": jnp.float32(0.9)}
    e1 = cached_engine(_loss_fn, data, cfg, scenario="gauss_markov",
                       scenario_params=params)
    e2 = cached_engine(_loss_fn, data, cfg, scenario="gauss_markov",
                       scenario_params={"corr": jnp.float32(0.9)})
    assert e2 is e1
    e3 = cached_engine(_loss_fn, data, cfg, scenario="gauss_markov",
                       scenario_params={"corr": jnp.float32(0.5)})
    assert e3 is not e1


def test_fused_backend_empty_rounds_finite(setup):
    """All-dropped rounds must not NaN the fused backend: its jnp oracle
    cancels the a=inf denoise scalar algebraically like the kernel does
    (the naive a·s → (…)/a composition produced 0·inf)."""
    data, params0, _ = setup
    cfg = POFLConfig(
        n_devices=12, n_scheduled=3, policy="pofl", seed=0,
        backend="pallas_fused",
    )
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="dropout",
        scenario_params={"p_drop": 0.85},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(50, dtype=jnp.int32), jnp.zeros(50, bool)
        )
    )(state)
    assert (np.asarray(recs.n_scheduled) == 0).any()  # empty rounds occurred
    assert np.isfinite(np.asarray(final.params["w"])).all()
    assert np.isfinite(np.asarray(recs.grad_norm)).all()


# --------------------------------------------------------------------------
# heterogeneous (Dirichlet-sized) shards
# --------------------------------------------------------------------------


def test_dirichlet_sizes_apportionment():
    sizes = dirichlet_sizes(1000, 8, beta=0.3, min_per_device=2, seed=0)
    assert sizes.sum() == 1000 and (sizes >= 2).all()
    near_equal = dirichlet_sizes(1000, 8, beta=1e6, seed=0)
    assert near_equal.max() - near_equal.min() <= 2  # β→∞ ⇒ ~equal shards
    with pytest.raises(ValueError):
        dirichlet_sizes(10, 8, min_per_device=2)


def test_hetero_lattice_end_to_end(setup):
    """Acceptance: a lattice sweep with Dirichlet-sized (unequal) shards runs
    end to end through engine + lattice, weights following the true m_i/M."""
    _, params0, ev = setup
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 1200, key)
    data = partition_dirichlet_sized(x, y, n_devices=12, beta=0.4, seed=0)
    frac = np.asarray(data.data_frac)
    assert frac.sum() == pytest.approx(1.0, rel=1e-6)
    assert frac.std() > 0.01  # genuinely non-uniform

    spec = LatticeSpec(
        policies=("pofl", "importance"), seeds=(0, 1000), n_rounds=6,
        eval_every=3,
    )
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=12, n_scheduled=4), eval_fn=ev,
    )
    assert recs.e_com.shape == (1, 2, 1, 1, 2, 6)
    assert np.isfinite(recs.e_com).all() and np.isfinite(recs.acc).all()
    assert (recs.n_scheduled >= 1).all()

    # and through the run_pofl wrapper (engine path) as well
    cfg = POFLConfig(n_devices=12, n_scheduled=4, seed=0)
    params, hist = run_pofl(_loss_fn, params0, data, cfg, 5, eval_fn=ev, eval_every=2)
    assert np.isfinite(np.asarray(params["w"])).all()
    assert hist.test_acc[-1] > 0.2  # it actually learns a bit in 5 rounds


def test_hetero_padding_never_sampled():
    """Padded rows carry NaN features here: any draw past a device's valid
    prefix would poison the gradients, so finiteness proves the sampler
    respects n_samples."""
    from repro.core import local_gradient_stage

    n_dev, m_max, d = 4, 10, 8
    feats = np.random.default_rng(0).normal(size=(n_dev, m_max, d)).astype(np.float32)
    labels = np.random.default_rng(1).integers(0, 3, size=(n_dev, m_max))
    n_samples = np.array([10, 3, 7, 1], np.int32)
    for i, ns in enumerate(n_samples):
        feats[i, ns:] = np.nan  # poison the padding
    data = DeviceData(
        features=jnp.asarray(feats), labels=jnp.asarray(labels),
        n_samples=n_samples,
    )

    def loss(params, x, y):
        logits = x @ params["w"]
        return -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
        )

    cfg = POFLConfig(n_devices=n_dev, batch_size=6)
    for seed in range(5):
        g = local_gradient_stage(
            loss, data, cfg, {"w": jnp.zeros((d, 3))}, jax.random.PRNGKey(seed)
        )
        assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_allclose(
        np.asarray(data.data_frac), n_samples / n_samples.sum(), rtol=1e-6
    )

    # empty devices are rejected at trace time, not silently wrapped onto
    # the last padded row
    empty = DeviceData(
        features=jnp.asarray(feats), labels=jnp.asarray(labels),
        n_samples=np.array([10, 0, 7, 1], np.int32),
    )
    with pytest.raises(ValueError, match="n_samples"):
        local_gradient_stage(
            loss, empty, cfg, {"w": jnp.zeros((d, 3))}, jax.random.PRNGKey(0)
        )


def test_dirichlet_mixed_pins_sizes_and_label_histograms():
    """dirichlet_mixed = dirichlet × dirichlet_sized in one preset: for a
    fixed seed both the shard sizes and the per-device label histograms are
    pinned, both skews are genuinely present, and every sample is used
    exactly once across the valid prefixes."""
    from repro.sim import make_partition

    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 2000, key)
    dd = make_partition(
        "dirichlet_mixed", x, y, n_devices=10, beta=0.3, beta_size=0.4, seed=0
    )
    # pinned shard sizes (Dir(0.4)·2000, largest-remainder, min 1/device)
    np.testing.assert_array_equal(
        dd.n_samples, [153, 1, 365, 135, 102, 484, 234, 502, 23, 1]
    )
    assert dd.features.shape == (10, 502, 784)
    np.testing.assert_allclose(
        np.asarray(dd.data_frac), np.asarray(dd.n_samples) / 2000.0, rtol=1e-6
    )
    # pinned device-0 label histogram (Dir(0.3) label proportions)
    hist0 = np.bincount(np.asarray(dd.labels[0][:153]), minlength=10)
    np.testing.assert_array_equal(hist0, [0, 3, 14, 89, 3, 6, 0, 0, 33, 5])

    # both skews present: sizes far from equal, labels far from uniform
    sizes = np.asarray(dd.n_samples)
    assert sizes.max() > 2 * sizes.min() and sizes.sum() == 2000
    top_fracs = []
    for d in range(10):
        lab = np.asarray(dd.labels[d][: sizes[d]])
        counts = np.bincount(lab, minlength=10)
        top_fracs.append(counts.max() / counts.sum())
    assert np.mean(top_fracs) > 0.35  # vs ≈0.1–0.2 for uniform labels

    # every sample used exactly once across valid prefixes (wrap-padding
    # reuses only a device's own rows, past its n_samples prefix)
    valid = np.concatenate(
        [np.asarray(dd.features[d][: sizes[d]]) for d in range(10)]
    )
    assert np.unique(valid, axis=0).shape[0] == 2000
    part_classes, part_counts = np.unique(
        np.concatenate([np.asarray(dd.labels[d][: sizes[d]]) for d in range(10)]),
        return_counts=True,
    )
    global_classes, global_counts = np.unique(np.asarray(y), return_counts=True)
    np.testing.assert_array_equal(part_classes, global_classes)
    np.testing.assert_array_equal(part_counts, global_counts)


@pytest.mark.parametrize(
    "scenario,params",
    [("dropout", {"p_drop": 0.5}), ("churn", {"p_depart": 0.3, "p_arrive": 0.2})],
)
def test_hetero_shards_under_availability_stay_finite(setup, scenario, params):
    """Dirichlet-sized (unequal m_i/M) shards composed with availability
    scenarios: trajectory, metrics and realized |S| stay finite/clamped —
    the engine-level counterpart of the scheduling-level property test
    (tests/test_scheduling.py::test_property_unbiased_and_finite_under_availability)."""
    _, params0, _ = setup
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 1200, key)
    data = partition_dirichlet_sized(x, y, n_devices=12, beta=0.4, seed=0)
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    engine = SimEngine(
        _loss_fn, data, cfg, scenario=scenario, scenario_params=params
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(30, dtype=jnp.int32), jnp.zeros(30, bool)
        )
    )(state)
    assert np.isfinite(np.asarray(final.params["w"])).all()
    assert np.isfinite(np.asarray(recs.e_com)).all()
    assert np.isfinite(np.asarray(recs.e_var)).all()
    n_sched = np.asarray(recs.n_scheduled)
    assert (n_sched <= 4).all() and n_sched.min() < 4  # clamping fired


# --------------------------------------------------------------------------
# churn scenario
# --------------------------------------------------------------------------


def test_churn_availability_trends_not_flickers():
    """Churn availability is a sticky Markov chain: stationary rate
    p_arrive/(p_arrive+p_depart) and lag-1 autocorr ≈ 1-p_arrive-p_depart
    (≫ 0, unlike dropout's i.i.d. flicker at autocorr 0)."""
    cfg = ChannelConfig(n_devices=24)
    p_dep, p_arr = 0.1, 0.3
    proc = make_channel_process("churn", cfg, p_depart=p_dep, p_arrive=p_arr)
    _, avails = _rollout(proc, jax.random.PRNGKey(2), 3000)
    av = np.asarray(avails)  # (T, N)
    assert set(np.unique(av)) <= {0.0, 1.0}

    stationary = p_arr / (p_arr + p_dep)
    np.testing.assert_allclose(av.mean(), stationary, atol=0.04)

    centered = av - av.mean(axis=0)
    autocorr = float(
        (centered[1:] * centered[:-1]).mean() / (centered**2).mean()
    )
    np.testing.assert_allclose(autocorr, 1.0 - p_arr - p_dep, atol=0.08)
    # devices genuinely stay offline for multi-round stretches
    run_lengths = []
    for dev in range(av.shape[1]):
        off = av[:, dev] == 0
        if off.any():
            edges = np.flatnonzero(np.diff(np.concatenate([[0], off, [0]])))
            run_lengths.extend((edges[1::2] - edges[::2]).tolist())
    assert np.mean(run_lengths) > 2.0  # E[offline sojourn] = 1/p_arrive ≈ 3.3


def test_churn_base_channel_untouched():
    """The fading trajectory under churn matches the base process exactly
    (churn only gates availability)."""
    cfg = ChannelConfig(n_devices=8)
    proc = make_channel_process("churn", cfg, base="gauss_markov", corr=0.9)
    base = make_channel_process("gauss_markov", cfg, corr=0.9)
    st_c = proc.init(jax.random.PRNGKey(4))
    # churn splits its init key: base state comes from split(key)[0]
    k_base, _ = jax.random.split(jax.random.PRNGKey(4))
    st_b = base.init(k_base)
    k = jax.random.PRNGKey(9)
    _, h_c, _ = proc.step(st_c, k)
    _, h_b, _ = base.step(st_b, jax.random.split(k)[0])
    np.testing.assert_array_equal(np.asarray(h_c), np.asarray(h_b))


def test_churn_engine_runs_finite(setup):
    data, params0, _ = setup
    cfg = POFLConfig(n_devices=12, n_scheduled=4, policy="pofl", seed=0)
    engine = SimEngine(
        _loss_fn, data, cfg, scenario="churn",
        scenario_params={"p_depart": 0.3, "p_arrive": 0.2},
    )
    state = engine.init(params0, 0)
    final, recs = jax.jit(
        lambda s: engine.scan_rounds(
            s, jnp.arange(30, dtype=jnp.int32), jnp.zeros(30, bool)
        )
    )(state)
    assert np.isfinite(np.asarray(final.params["w"])).all()
    assert np.isfinite(np.asarray(recs.e_com)).all()
    n_sched = np.asarray(recs.n_scheduled)
    assert (n_sched <= 4).all() and n_sched.min() < 4  # clamping fired


def test_churn_dirichlet_mixed_golden_trajectory():
    """Seed-pinned golden trajectory for churn availability × dirichlet_mixed
    shards — the one PR-2/PR-3 feature pair that previously had no
    end-to-end pin (churn was pinned on equal shards, dirichlet_mixed only at
    the partition level). Any change to the PRNG key discipline, the Markov
    availability chain, the mixed-partition apportionment, or the Eq. 34-37
    weighting of unequal m_i/M moves these numbers and must be deliberate.

    The pinned ``n_scheduled`` run (2, 1, 4, 3, 4, 4) doubles as a structural
    check: churn genuinely clamps |S^t| below n_scheduled=4 on early rounds.
    """
    key = jax.random.PRNGKey(3)
    x, y = make_classification_dataset("mnist_like", 600, key)
    data = partition_dirichlet_mixed(
        x, y, n_devices=10, beta=0.3, beta_size=0.4, seed=0
    )
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}
    spec = LatticeSpec(
        policies=("pofl",), noise_powers=(1e-11,), alphas=(0.1,), seeds=(0,),
        n_rounds=6,
    )
    recs = run_lattice(
        _loss_fn, data, params0, spec,
        base_cfg=POFLConfig(n_devices=10, n_scheduled=4),
        scenario="churn",
        scenario_params={"p_depart": 0.3, "p_arrive": 0.2},
    )
    cell = {f: np.asarray(getattr(recs, f)[0, 0, 0, 0, 0]) for f in
            ("e_com", "e_var", "grad_norm", "n_scheduled")}
    np.testing.assert_array_equal(
        cell["n_scheduled"], [2.0, 1.0, 4.0, 3.0, 4.0, 4.0]
    )
    golden = {
        "e_com": [0.031349364668130875, 0.001395408296957612,
                  0.012313947081565857, 0.02131267450749874,
                  0.03685463219881058, 0.007252929266542196],
        "e_var": [0.1070418655872345, 0.12386903166770935,
                  0.07931140810251236, 0.08480053395032883,
                  0.08735901862382889, 0.15798714756965637],
        "grad_norm": [0.20976485311985016, 0.06041086092591286,
                      0.18663346767425537, 0.2160150557756424,
                      0.219487726688385, 0.11000669002532959],
    }
    for f, want in golden.items():
        np.testing.assert_allclose(cell[f], want, rtol=1e-5, err_msg=f)


# --------------------------------------------------------------------------
# trial-batched fused kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("bt,n,d", [(1, 4, 512), (3, 12, 700), (5, 30, 1024)])
def test_aircomp_fused_batch_matches_ref(bt, n, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    g = jax.random.normal(ks[0], (bt, n, d))
    coeff = jax.random.uniform(ks[1], (bt, n)) * (
        jax.random.uniform(ks[2], (bt, n)) > 0.3
    )
    z = jax.random.normal(ks[3], (bt, d))
    m_g = 0.1 * jax.random.normal(ks[4], (bt,))
    v_g = jax.random.uniform(ks[5], (bt,)) + 0.2
    a = jnp.linspace(1.0, 3.0, bt)

    got = aircomp_fused_batch(g, coeff, m_g, v_g, a, z, interpret=True)
    want = aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
