"""Dry-run machinery on a small host mesh (8 devices): lower + compile +
memory/cost/collective extraction — the same code path as the production
512-chip run, at reduced scale. (Run via test_distributed_launcher.)"""
from __future__ import annotations

import jax
import pytest

from repro import configs
from repro.launch.dryrun import cost_analysis_dict, parse_collective_bytes
from repro.launch.mesh import activate_mesh, make_host_mesh
from repro.launch.steps import build_step
from repro.models.config import InputShape


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs ≥4 devices (run via test_distributed_launcher)")
    return make_host_mesh(model=2)


SHAPES = {
    "train": InputShape("t", seq_len=32, global_batch=8, kind="train"),
    "prefill": InputShape("p", seq_len=32, global_batch=8, kind="prefill"),
    "decode": InputShape("d", seq_len=64, global_batch=8, kind="decode"),
}


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "olmoe-1b-7b", "mamba2-370m",
                                     "zamba2-2.7b", "seamless-m4t-large-v2",
                                     "internvl2-76b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_small(mesh, arch_id, kind):
    cfg = configs.reduced_config(arch_id)
    shape = SHAPES[kind]
    with activate_mesh(mesh):
        bundle = build_step(cfg, shape, mesh)
        lowered = bundle.fn.lower(*bundle.arg_structs.values())
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    cost = cost_analysis_dict(compiled.cost_analysis())
    assert cost.get("flops", 0) > 0
    coll = parse_collective_bytes(compiled.as_text())
    # a sharded train/prefill step must communicate *something*
    if kind == "train":
        assert sum(v["bytes"] for v in coll.values()) > 0, coll


def test_collective_parser_units():
    txt = """
  %all-gather.1 = bf16[16,256]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.2 = f32[128]{0} all-reduce(%x), channel_id=2, replica_groups=[2,128]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), channel_id=3, replica_groups=[16,16]<=[256], dimensions={0}
  %cp = u32[2]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %not_a_collective = f32[4]{0} add(%a, %b)
"""
    got = parse_collective_bytes(txt)
    assert got["all-gather"]["count"] == 1
    assert got["all-gather"]["bytes"] == 16 * 256 * 2 * 15 // 16
    assert got["all-reduce"]["bytes"] == 2 * 128 * 4 * 127 // 128
    assert got["reduce-scatter"]["bytes"] == 64 * 4 * 15
    assert got["collective-permute"]["bytes"] == 8
    assert "add" not in got
