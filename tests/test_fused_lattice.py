"""Policy-fused lattice suite (ISSUE 5 tentpole pin).

Contracts pinned here:

  * a multi-policy ``LatticeSpec`` (≥3 policies) compiles exactly ONE
    lattice program: one engine-cache entry (the ``FUSED_POLICY`` sentinel),
    ``n_lattice_traces == 1``, ``n_compiles == 1`` — and an identical repeat
    call re-traces and re-compiles ZERO times with bit-identical records;
  * the ``fuse_policies=False`` per-policy fallback (same traced-dispatch
    cell program, constant policy axis, one smaller compile per policy) is
    BIT-IDENTICAL to the fused path — unmeshed, on a 1-device mesh, on the
    8-fake-device mesh, for the ``jnp`` and ``pallas_fused``-interpret
    backends, and for the ``topk`` sampler;
  * the engine's AOT ``lower().compile()`` path exposes per-program
    ``cost_analysis`` / ``memory_analysis`` and a ``compile_seconds``
    counter;
  * the traced ``lax.switch`` dispatch tracks the historical ``cfg.policy``
    string dispatch: bitwise at the ``scheduling_probs`` level (see
    tests/test_scheduling.py), and at whole-trajectory level dtype-exact up
    to the documented ≤1-ULP cross-program reduction wobble (the same
    carve-out PR 4 established for multi-host ``e_var``).

The 8-device legs run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the sharded-8dev CI job) and skip elsewhere.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig
from repro.core.scheduling import POLICY_IDS
from repro.data import make_classification_dataset, partition_noniid_shards
from repro.sim import (
    FUSED_ALGORITHM,
    FUSED_POLICY,
    LatticeSpec,
    cached_engine,
    engine_cache_stats,
    lattice_compile_stats,
    make_cell_mesh,
    run_lattice,
)

N_VISIBLE = len(jax.devices())
needs_8_devices = pytest.mark.skipif(
    N_VISIBLE < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

_RECORD_FIELDS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")

MULTI_POLICY_SPEC = LatticeSpec(
    policies=("pofl", "importance", "channel", "noisefree", "deterministic"),
    noise_powers=(1e-11, 1e-9),
    seeds=(0, 1000),
    n_rounds=3,
    eval_every=2,
)

# the ISSUE-8 acceptance grid: (2 algorithms × 2 policies × noise × seeds)
MULTI_ALG_SPEC = LatticeSpec(
    policies=("pofl", "channel"),
    noise_powers=(1e-11, 1e-9),
    seeds=(0, 1000),
    n_rounds=3,
    eval_every=2,
    algorithms=("fedavg", "fedprox"),
)
# multi-step + a real proximal pull so the two algorithm lanes genuinely
# diverge (fedprox ≡ fedavg at local_steps=1 / μ→0 would hide a wiring bug)
MULTI_ALG_CFG = dict(local_steps=2, fedprox_mu=0.05)


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_noniid_shards(x, y, n_devices=8)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def ev(p):
        logits = x[:200] @ p["w"] + p["b"]
        return _loss_fn(p, x[:200], y[:200]), jnp.mean(
            jnp.argmax(logits, -1) == y[:200]
        )

    return data, params0, ev


def _assert_bit_identical(a, b, ulp_fields=()):
    """Dtype-exact structured equality; ``ulp_fields`` relaxes named fields
    to rtol 1e-6 where two program SHAPES (not values) are being compared —
    the documented ≤1-ULP cross-program reduction wobble (PR-4 precedent)."""
    assert a.axes == b.axes
    np.testing.assert_array_equal(a.eval_rounds, b.eval_rounds)
    for f in _RECORD_FIELDS:
        fa, fb = getattr(a, f), getattr(b, f)
        assert fa.shape == fb.shape, f
        assert fa.dtype == fb.dtype, f
        if f in ulp_fields:
            np.testing.assert_allclose(fa, fb, rtol=1e-6, err_msg=f)
        else:
            np.testing.assert_array_equal(fa, fb, err_msg=f)


def _sweep(setup, mesh=None, spec=MULTI_POLICY_SPEC, fuse=True, fuse_algs=True,
           **cfg_kw):
    data, params0, ev = setup
    cfg = POFLConfig(n_devices=8, n_scheduled=3, **cfg_kw)
    return run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh,
        fuse_policies=fuse, fuse_algorithms=fuse_algs,
    )


def _fused_engine(setup, mesh=None, **cfg_kw):
    data, _, ev = setup
    cfg = POFLConfig(n_devices=8, n_scheduled=3, policy=FUSED_POLICY, **cfg_kw)
    return cached_engine(_loss_fn, data, cfg, eval_fn=ev, mesh=mesh)


def _fused_alg_engine(setup, mesh=None, **cfg_kw):
    data, _, ev = setup
    cfg = POFLConfig(
        n_devices=8, n_scheduled=3, policy=FUSED_POLICY,
        local_algorithm=FUSED_ALGORITHM, **cfg_kw,
    )
    return cached_engine(_loss_fn, data, cfg, eval_fn=ev, mesh=mesh)


# --------------------------------------------------------------------------
# acceptance: one engine, one trace, one compile for a ≥3-policy lattice
# --------------------------------------------------------------------------


def test_multi_policy_lattice_compiles_once(setup):
    """5 policies × 2 noise × 2 seeds: ONE engine-cache miss, ONE trace, ONE
    XLA compile — and the repeat call adds none of the three, returning
    bit-identical records."""
    assert len(MULTI_POLICY_SPEC.policies) >= 3
    first = _sweep(setup)
    stats = engine_cache_stats()
    assert stats["misses"] == 1, stats
    engine = _fused_engine(setup)
    assert engine.n_lattice_traces == 1
    assert engine.n_compiles == 1
    assert engine.compile_seconds > 0.0
    assert lattice_compile_stats() == {
        "n_compiles": 1, "compile_seconds": engine.compile_seconds,
    }

    repeat = _sweep(setup)
    assert engine.n_lattice_traces == 1  # ZERO retraces
    assert engine.n_compiles == 1        # ZERO recompiles
    assert engine_cache_stats()["misses"] == 1
    _assert_bit_identical(first, repeat)


def test_fallback_pays_one_compile_per_policy(setup):
    """The fuse_policies=False loop is the old cost model: one engine and
    one (smaller) compile per policy — the number the fused path collapses."""
    _sweep(setup, fuse=False)
    stats = engine_cache_stats()
    assert stats["misses"] == len(MULTI_POLICY_SPEC.policies)
    cs = lattice_compile_stats()
    assert cs["n_compiles"] == len(MULTI_POLICY_SPEC.policies)


# --------------------------------------------------------------------------
# fused ≡ fallback, bit for bit, across backends / mesh / sampler
# --------------------------------------------------------------------------


def test_fused_matches_fallback_unmeshed(setup):
    _assert_bit_identical(_sweep(setup), _sweep(setup, fuse=False))


def test_fused_matches_fallback_one_device_mesh(setup):
    mesh = make_cell_mesh(1)
    fused = _sweep(setup, mesh=mesh)
    _assert_bit_identical(fused, _sweep(setup, mesh=mesh, fuse=False))
    # and the meshed fused lattice is the unmeshed fused lattice, bit for bit
    _assert_bit_identical(fused, _sweep(setup))


def test_fused_matches_fallback_pallas_interpret(setup, monkeypatch):
    """The pallas_fused aggregation backend (interpret-mode kernel on CPU)
    composes with the traced policy dispatch: fused ≡ fallback bitwise."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    spec = dataclasses.replace(MULTI_POLICY_SPEC, seeds=(0,))
    fused = _sweep(setup, spec=spec, backend="pallas_fused")
    fallback = _sweep(setup, spec=spec, fuse=False, backend="pallas_fused")
    _assert_bit_identical(fused, fallback)


def test_fused_matches_fallback_bernoulli_sampler(setup):
    """The PO-FL-B Horvitz–Thompson sampler's fused select (is_det over
    bernoulli vs deterministic weights/masks, both branches drawing from the
    same k_sched) matches the per-policy fallback bit for bit."""
    spec = dataclasses.replace(MULTI_POLICY_SPEC, seeds=(0,))
    fused = _sweep(setup, spec=spec, sampler="bernoulli")
    fallback = _sweep(setup, spec=spec, fuse=False, sampler="bernoulli")
    _assert_bit_identical(fused, fallback)


def test_fused_matches_fallback_topk_sampler(setup):
    """The Gumbel top-k sampler fast path rides the fused dispatch too.
    The top-k program shape happens to fuse the eval-loss reduction
    differently at the two batch sizes (fused 20 cells vs fallback 4), so
    ``loss`` gets the ULP carve-out; every trajectory field stays exact."""
    spec = dataclasses.replace(MULTI_POLICY_SPEC, seeds=(0,))
    fused = _sweep(setup, spec=spec, sampler="topk")
    fallback = _sweep(setup, spec=spec, fuse=False, sampler="topk")
    _assert_bit_identical(fused, fallback, ulp_fields=("loss",))
    assert np.isfinite(fused.e_com).all()
    assert (fused.n_scheduled <= 3).all() and (fused.n_scheduled >= 1).all()


@needs_8_devices
def test_fused_matches_fallback_eight_device_mesh(setup):
    """Acceptance (meshed): the policy-spanning cell axis shards over 8 fake
    devices (20 real cells padded to 24; the fallback pads 4 → 8 per policy)
    and fused ≡ fallback ≡ unmeshed-fused, bit for bit."""
    mesh = make_cell_mesh(8)
    fused = _sweep(setup, mesh=mesh)
    _assert_bit_identical(fused, _sweep(setup, mesh=mesh, fuse=False))
    _assert_bit_identical(fused, _sweep(setup))


# --------------------------------------------------------------------------
# traced switch vs historical string dispatch (documented ULP carve-out)
# --------------------------------------------------------------------------


def test_traced_dispatch_tracks_string_dispatch(setup):
    """Same engine, same cells, policy dispatched by traced id vs by the
    historical cfg.policy string: the two are DIFFERENT XLA programs, so
    reduction outputs may wobble by ≤1 ULP (exactly the PR-4 multi-host
    ``e_var`` phenomenon) — pinned here at rtol 1e-6 with the integer
    ``n_scheduled`` exact. The bitwise contract for the switch itself lives
    at the ``scheduling_probs_by_id`` level (tests/test_scheduling.py)."""
    data, params0, ev = setup
    t_ints = np.arange(3, dtype=np.int32)
    do_eval = np.zeros(3, bool)
    noise_b = jnp.full((4,), 1e-9, jnp.float32)
    alpha_b = jnp.full((4,), 0.1, jnp.float32)
    seed_b = jnp.arange(4, dtype=jnp.int32) * 1000
    for policy in ("pofl", "deterministic", "noisefree"):
        cfg = POFLConfig(n_devices=8, n_scheduled=3, policy=policy)
        engine = cached_engine(_loss_fn, data, cfg, eval_fn=ev)
        by_string = engine.run_lattice_cells(
            params0, t_ints, do_eval, noise_b, alpha_b, seed_b
        )
        by_id = engine.run_lattice_cells(
            params0, t_ints, do_eval, noise_b, alpha_b, seed_b,
            policy_b=jnp.full((4,), POLICY_IDS[policy], jnp.int32),
        )
        np.testing.assert_array_equal(
            np.asarray(by_string.n_scheduled), np.asarray(by_id.n_scheduled),
            err_msg=policy,
        )
        for f in ("e_com", "e_var", "grad_norm", "loss", "acc"):
            np.testing.assert_allclose(
                np.asarray(getattr(by_string, f)), np.asarray(getattr(by_id, f)),
                rtol=1e-6, err_msg=f"{policy}:{f}",
            )


# --------------------------------------------------------------------------
# AOT program introspection
# --------------------------------------------------------------------------


def test_aot_exposes_cost_and_memory_analysis(setup):
    spec = LatticeSpec(policies=("pofl", "channel"), seeds=(0,), n_rounds=2)
    _sweep(setup, spec=spec)
    engine = _fused_engine(setup)
    cost = engine.lattice_cost_analysis()
    assert cost and any("flops" in k for k in cost)
    mem = engine.lattice_memory_analysis()
    assert mem is not None and mem.output_size_in_bytes > 0
    assert engine.compile_seconds > 0.0 and engine.n_compiles == 1


# --------------------------------------------------------------------------
# traced local_algorithm axis (ISSUE 8): one compile, fallback parity
# --------------------------------------------------------------------------


def test_multi_algorithm_lattice_compiles_once(setup):
    """Acceptance: the (2 algorithms × 2 policies × 2 noise × 2 seeds)
    lattice is ONE engine-cache miss (the FUSED_ALGORITHM + FUSED_POLICY
    sentinels), ONE trace, ONE XLA compile — and the repeat call adds none
    of the three, returning bit-identical records."""
    first = _sweep(setup, spec=MULTI_ALG_SPEC, **MULTI_ALG_CFG)
    assert engine_cache_stats()["misses"] == 1
    engine = _fused_alg_engine(setup, **MULTI_ALG_CFG)
    assert engine.n_lattice_traces == 1
    assert engine.n_compiles == 1
    assert lattice_compile_stats()["n_compiles"] == 1

    repeat = _sweep(setup, spec=MULTI_ALG_SPEC, **MULTI_ALG_CFG)
    assert engine.n_lattice_traces == 1  # ZERO retraces
    assert engine.n_compiles == 1        # ZERO recompiles
    assert engine_cache_stats()["misses"] == 1
    _assert_bit_identical(first, repeat)
    assert first.axes["algorithm"] == ["fedavg", "fedprox"]
    assert first.e_com.shape == (2, 2, 2, 1, 2, MULTI_ALG_SPEC.n_rounds)
    # the two algorithm lanes genuinely diverge (μ > 0, 2 local steps)
    assert not np.array_equal(first.grad_norm[0], first.grad_norm[1])


def test_fused_algorithms_match_fallback_unmeshed(setup):
    """fuse_algorithms=False re-runs each algorithm as a forced
    single-algorithm lattice over the SAME traced-dispatch cell program
    (constant algorithm_id) — one engine + one compile per algorithm, records
    bit-identical to the fused lanes."""
    fused = _sweep(setup, spec=MULTI_ALG_SPEC, **MULTI_ALG_CFG)
    fallback = _sweep(setup, spec=MULTI_ALG_SPEC, fuse_algs=False,
                      **MULTI_ALG_CFG)
    _assert_bit_identical(fused, fallback)
    # fused engine + one per-algorithm engine each → 1 + len(algorithms)
    assert engine_cache_stats()["misses"] == 1 + len(MULTI_ALG_SPEC.algorithms)


def test_fused_algorithms_match_fallback_pallas_interpret(setup, monkeypatch):
    """The pallas_fused aggregation backend (interpret-mode kernel on CPU)
    composes with the traced algorithm dispatch: fused ≡ fallback bitwise."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    spec = dataclasses.replace(MULTI_ALG_SPEC, seeds=(0,))
    fused = _sweep(setup, spec=spec, backend="pallas_fused", **MULTI_ALG_CFG)
    fallback = _sweep(setup, spec=spec, fuse_algs=False,
                      backend="pallas_fused", **MULTI_ALG_CFG)
    _assert_bit_identical(fused, fallback)


def test_fused_algorithms_one_device_mesh(setup):
    """(C, 1) mesh leg: the algorithm-spanning cell axis on a 1-device mesh
    is bit-identical to the unmeshed run, fused and fallback alike."""
    mesh = make_cell_mesh(1)
    fused = _sweep(setup, spec=MULTI_ALG_SPEC, mesh=mesh, **MULTI_ALG_CFG)
    _assert_bit_identical(
        fused,
        _sweep(setup, spec=MULTI_ALG_SPEC, mesh=mesh, fuse_algs=False,
               **MULTI_ALG_CFG),
    )
    _assert_bit_identical(fused, _sweep(setup, spec=MULTI_ALG_SPEC,
                                        **MULTI_ALG_CFG))


@needs_8_devices
def test_fused_algorithms_eight_device_mesh(setup):
    """8-fake-device leg: 16 real cells sharded over 8 devices — fused ≡
    fallback ≡ unmeshed-fused, bit for bit."""
    mesh = make_cell_mesh(8)
    fused = _sweep(setup, spec=MULTI_ALG_SPEC, mesh=mesh, **MULTI_ALG_CFG)
    _assert_bit_identical(
        fused,
        _sweep(setup, spec=MULTI_ALG_SPEC, mesh=mesh, fuse_algs=False,
               **MULTI_ALG_CFG),
    )
    _assert_bit_identical(fused, _sweep(setup, spec=MULTI_ALG_SPEC,
                                        **MULTI_ALG_CFG))


def test_aot_cache_distinguishes_signatures(setup):
    """A different cell-axis length is a different executable (one more
    compile), but repeating either signature costs nothing new."""
    spec2 = LatticeSpec(policies=("pofl", "channel"), seeds=(0, 1), n_rounds=2)
    spec3 = dataclasses.replace(spec2, seeds=(0, 1, 2))
    _sweep(setup, spec=spec2)
    engine = _fused_engine(setup)
    assert engine.n_compiles == 1
    _sweep(setup, spec=spec3)
    assert engine.n_compiles == 2
    _sweep(setup, spec=spec2)
    _sweep(setup, spec=spec3)
    assert engine.n_compiles == 2
