"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aircomp import (
    aircomp_fused,
    aircomp_fused_batch,
    aircomp_fused_batch_ref,
    aircomp_fused_ref,
)
from repro.kernels.aircomp.kernel import DEFAULT_TILE_D, _clamp_tile
from repro.kernels.attention import flash_attention, mha_ref
from repro.kernels.ssd import ssd_chunked_ref, ssd_naive, ssd_pallas

# --------------------------------------------------------------------------
# aircomp fused
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(4, 512), (30, 1024), (7, 700), (1, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aircomp_fused_matches_ref(n, d, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    g = jax.random.normal(ks[0], (n, d), dtype)
    coeff = jax.random.uniform(ks[1], (n,)) * (
        jax.random.uniform(ks[2], (n,)) > 0.3
    )
    z = jax.random.normal(ks[3], (d,), dtype)
    m_g = jnp.float32(0.13)
    v_g = jnp.float32(0.7)
    a = jnp.float32(2.4)

    got = aircomp_fused(g, coeff, m_g, v_g, a, z, interpret=True)
    want = aircomp_fused_ref(
        g.astype(jnp.float32), coeff, m_g, v_g, a, z.astype(jnp.float32)
    )
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


# D values off the tile grid: not multiples of tile_d, including D < tile_d
# (a model-mesh shard's local block) where the tile must CLAMP to the
# 128-lane-aligned D instead of padding a near-empty DEFAULT_TILE_D grid
_ODD_DIMS = (64, 100, 128, 300, 512 + 1, 981, 2 * 512 + 17)


@pytest.mark.parametrize("d", _ODD_DIMS)
def test_aircomp_fused_padding_property(d):
    key = jax.random.PRNGKey(d)
    ks = jax.random.split(key, 4)
    n = 6
    g = jax.random.normal(ks[0], (n, d))
    coeff = jax.random.uniform(ks[1], (n,)) * (
        jax.random.uniform(ks[2], (n,)) > 0.3
    )
    z = jax.random.normal(ks[3], (d,))
    m_g, v_g, a = jnp.float32(0.21), jnp.float32(0.9), jnp.float32(1.7)

    got = aircomp_fused(g, coeff, m_g, v_g, a, z, interpret=True)
    want = aircomp_fused_ref(g, coeff, m_g, v_g, a, z)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d", _ODD_DIMS)
def test_aircomp_fused_batch_padding_property(d):
    key = jax.random.PRNGKey(1000 + d)
    ks = jax.random.split(key, 6)
    bt, n = 3, 5
    g = jax.random.normal(ks[0], (bt, n, d))
    coeff = jax.random.uniform(ks[1], (bt, n)) * (
        jax.random.uniform(ks[2], (bt, n)) > 0.3
    )
    z = jax.random.normal(ks[3], (bt, d))
    m_g = jax.random.normal(ks[4], (bt,)) * 0.1
    v_g = jax.random.uniform(ks[5], (bt,)) + 0.5
    a = jnp.full((bt,), 2.0)

    got = aircomp_fused_batch(g, coeff, m_g, v_g, a, z, interpret=True)
    want = aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z)
    assert got.shape == (bt, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_clamp_tile_rule():
    # oversized default tile clamps to the 128-lane-aligned D...
    assert _clamp_tile(100, DEFAULT_TILE_D) == 128
    assert _clamp_tile(128, DEFAULT_TILE_D) == 128
    assert _clamp_tile(300, DEFAULT_TILE_D) == 384
    # ...never past D's own tile when D is large...
    assert _clamp_tile(7850, DEFAULT_TILE_D) == DEFAULT_TILE_D
    assert _clamp_tile(DEFAULT_TILE_D, DEFAULT_TILE_D) == DEFAULT_TILE_D
    # ...and a caller-requested SMALL tile passes through untouched
    assert _clamp_tile(512, 8) == 8
    assert _clamp_tile(4, 8) == 8


def test_aircomp_fused_zero_noise_is_weighted_sum():
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, (8, 512))
    coeff = jnp.ones((8,)) / 8
    out = aircomp_fused(
        g, coeff, jnp.float32(0.0), jnp.float32(1.0), jnp.float32(1.0),
        jnp.zeros((512,)), interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(g.mean(0)), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# ssd
# --------------------------------------------------------------------------


def _ssd_inputs(key, b, s, h, p, n, dtype):
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p), dtype)
    # realistic log decays in [-3, 0)
    la = -jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.01, 3.0)
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    return xdt, la, B, C


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 4, 32, 16, 16),
    (1, 128, 2, 64, 64, 32),
    (3, 32, 8, 16, 8, 32),   # chunk == s
])
def test_ssd_chunked_ref_matches_naive(b, s, h, p, n, chunk):
    xdt, la, B, C = _ssd_inputs(jax.random.PRNGKey(0), b, s, h, p, n, jnp.float32)
    got = ssd_chunked_ref(xdt, la, B, C, chunk)
    want = ssd_naive(xdt, la, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 4, 32, 16, 16),
    (1, 128, 2, 64, 64, 32),
    (2, 32, 8, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_matches_naive(b, s, h, p, n, chunk, dtype):
    xdt, la, B, C = _ssd_inputs(jax.random.PRNGKey(1), b, s, h, p, n, dtype)
    got = ssd_pallas(xdt, la, B.astype(dtype), C.astype(dtype), chunk=chunk, interpret=True)
    want = ssd_naive(
        xdt.astype(jnp.float32), la, B.astype(jnp.float32), C.astype(jnp.float32)
    )
    tol = 5e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_ssd_pallas_state_reset_across_batch():
    """The scratch state must reset at chunk 0 of every batch row —
    batch rows are independent."""
    xdt, la, B, C = _ssd_inputs(jax.random.PRNGKey(2), 3, 64, 2, 16, 8, jnp.float32)
    full = ssd_pallas(xdt, la, B, C, chunk=16, interpret=True)
    # row 2 computed alone must equal row 2 of the batched run
    solo = ssd_pallas(xdt[2:], la[2:], B[2:], C[2:], chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(full[2:]), np.asarray(solo), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,sq,sk,h,kv,dh,bq,bk", [
    (2, 64, 64, 4, 4, 32, 16, 16),    # MHA causal
    (1, 128, 128, 8, 2, 64, 32, 32),  # GQA 4:1
    (2, 64, 64, 4, 1, 32, 64, 16),    # MQA, single q block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_matches_ref(b, sq, sk, h, kv, dh, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, dh), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    want = mha_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("window", [16, 32, 100])
def test_flash_sliding_window_matches_ref(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, kv, dh = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    got = flash_attention(
        q, k, v, causal=True, sliding_window=window,
        block_q=32, block_k=32, interpret=True,
    )
    want = mha_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_non_causal_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, sq, sk, h, dh = 2, 32, 64, 2, 32
    q = jax.random.normal(ks[0], (b, sq, h, dh))
    k = jax.random.normal(ks[1], (b, sk, h, dh))
    v = jax.random.normal(ks[2], (b, sk, h, dh))
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32, interpret=True)
    want = mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_q_offset_decode_tail():
    """q_offset places the query block at the end of a longer context
    (chunked prefill / speculative-decode pattern)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, sk, h, dh = 1, 128, 2, 32
    sq, off = 32, 96
    k = jax.random.normal(ks[1], (b, sk, h, dh))
    v = jax.random.normal(ks[2], (b, sk, h, dh))
    q = jax.random.normal(ks[0], (b, sq, h, dh))
    got = flash_attention(
        q, k, v, causal=True, q_offset=off, block_q=32, block_k=32, interpret=True
    )
    want = mha_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
