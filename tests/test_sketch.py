"""JVP-sketched per-device gradient statistics vs exact values."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import sketch_device_stats


def _quadratic_setup(key, n_dev=6, dim=50):
    """Per-device loss L_d(p) = 0.5·||p − c_d||² → g_d = p − c_d exactly."""
    centers = jax.random.normal(key, (n_dev, dim))
    params = {"p": jnp.zeros((dim,))}

    def per_device_loss(params):
        diff = params["p"][None, :] - centers
        return 0.5 * jnp.sum(diff**2, axis=-1)

    grads = -centers  # at p = 0
    return per_device_loss, params, grads


def test_mean_is_exact():
    f, params, g = _quadratic_setup(jax.random.PRNGKey(0))
    stats = sketch_device_stats(f, params, jax.random.PRNGKey(1), n_probes=1)
    np.testing.assert_allclose(
        np.asarray(stats.mean), np.asarray(g.mean(axis=-1)), rtol=1e-5, atol=1e-6
    )


def test_norm_is_unbiased():
    """E[(g·v)²] = ‖g‖²: the probe-averaged estimate converges."""
    f, params, g = _quadratic_setup(jax.random.PRNGKey(2), dim=200)
    true_norms = np.asarray(jnp.linalg.norm(g, axis=-1))
    errs = []
    for probes in (8, 128):
        stats = sketch_device_stats(f, params, jax.random.PRNGKey(3), n_probes=probes)
        errs.append(np.mean(np.abs(np.asarray(stats.norm) - true_norms) / true_norms))
    assert errs[1] < errs[0], errs       # error shrinks with probes
    assert errs[1] < 0.15, errs          # ~sqrt(2/128) ≈ 0.12


def test_var_nonnegative_and_close():
    f, params, g = _quadratic_setup(jax.random.PRNGKey(4), dim=300)
    stats = sketch_device_stats(f, params, jax.random.PRNGKey(5), n_probes=128)
    true_var = np.asarray(jnp.var(g, axis=-1))
    assert np.all(np.asarray(stats.var) >= 0)
    np.testing.assert_allclose(np.asarray(stats.var), true_var, rtol=0.5)
