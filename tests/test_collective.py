"""The AirComp noisy all-reduce (shard_map) must agree with the reference
aggregation in core/aircomp.py. Runs on a virtual multi-device CPU mesh —
conftest does NOT set XLA_FLAGS globally, so this module spawns a subprocess
with 8 virtual devices for the mesh test and runs in-process checks on 1."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aircomp, collective


def test_aircomp_allreduce_single_device_semantics():
    """On a 1-device 'mesh' the psum is identity: check weighting+noise math."""
    g = {"w": jnp.arange(8.0), "b": jnp.ones((3,))}
    key = jax.random.PRNGKey(0)
    out = collective.aircomp_allreduce(g, jnp.asarray(2.0), jnp.asarray(0.0), key, ())
    np.testing.assert_allclose(out["w"], 2.0 * g["w"])
    np.testing.assert_allclose(out["b"], 2.0 * g["b"])


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import aircomp, collective
    from repro.launch.mesh import activate_mesh

    mesh = jax.make_mesh((8,), ("data",))
    n, dim = 8, 64
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    g = jax.random.normal(k1, (n, dim))
    h = (jax.random.normal(k2, (n,)) + 1j*jax.random.normal(k3, (n,)))/jnp.sqrt(2)
    rho = jnp.linspace(0.05, 0.2, n)
    mask = (jnp.arange(n) % 2 == 0).astype(jnp.float32)

    # reference (single-host Eq.16 path)
    noise_key = jax.random.PRNGKey(5)
    y_ref, _ = aircomp.aircomp_aggregate(
        g, rho, h, mask, noise_key, 1.0, 1e-4, simulate_physical=False)

    # distributed twin: coeffs = mask*rho, noise_amp = sqrt(V_g)/a
    stats = aircomp.local_stats(g)
    _, v_g = aircomp.global_stats(stats, rho, mask)
    a = aircomp.denoise_scalar(rho, jnp.abs(h), mask, 1.0)
    amp = jnp.sqrt(v_g)/a

    with activate_mesh(mesh):
        agg = collective.make_sharded_aggregator(mesh, "data")
        y_dist = agg(g, mask*rho, jnp.asarray(0.0), jax.random.PRNGKey(5))
    # zero-noise comparison isolates the weighted psum
    y_ref0, _ = aircomp.aircomp_aggregate(
        g, rho, h, mask, noise_key, 1.0, 0.0, simulate_physical=False)
    np.testing.assert_allclose(np.asarray(y_dist), np.asarray(y_ref0), rtol=1e-5, atol=1e-6)
    print("OK")
    """
)


def test_sharded_aggregator_matches_reference_on_8dev_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, env=env, cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
