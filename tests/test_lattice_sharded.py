"""Mesh-sharded scenario-lattice parity/property suite (ISSUE 3 tentpole pin).

Contracts pinned here:

  * a 1-device mesh is BIT-IDENTICAL to the unsharded path (same structured
    records, same order) — always runs, any device count;
  * an 8-fake-device mesh matches the unsharded path dtype-exactly, for
    divisible and non-divisible (padded) grids — runs under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the dedicated CI
    leg; skipped when fewer devices are visible);
  * engine-cache keys distinguish meshed from unmeshed engines, and repeat
    sharded ``run_lattice`` calls re-trace ZERO times.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import POFLConfig
from repro.data import make_classification_dataset, partition_dirichlet_sized, partition_noniid_shards
from repro.sim import (
    FUSED_POLICY,
    LatticeRecords,
    LatticeSpec,
    cached_engine,
    engine_cache_stats,
    make_cell_mesh,
    run_lattice,
)

N_VISIBLE = len(jax.devices())
needs_8_devices = pytest.mark.skipif(
    N_VISIBLE < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

_RECORD_FIELDS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")


def _loss_fn(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_noniid_shards(x, y, n_devices=8)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def ev(p):
        logits = x[:200] @ p["w"] + p["b"]
        return _loss_fn(p, x[:200], y[:200]), jnp.mean(
            jnp.argmax(logits, -1) == y[:200]
        )

    return data, params0, ev


def _assert_records_equal(a: LatticeRecords, b: LatticeRecords):
    """Dtype-exact equality of the full structured output, order included."""
    assert a.axes == b.axes
    np.testing.assert_array_equal(a.eval_rounds, b.eval_rounds)
    for f in _RECORD_FIELDS:
        fa, fb = getattr(a, f), getattr(b, f)
        assert fa.shape == fb.shape, f
        assert fa.dtype == fb.dtype, f
        np.testing.assert_array_equal(fa, fb, err_msg=f)


def _sweep(setup, mesh, spec=None, **cfg_kw):
    data, params0, ev = setup
    spec = spec or LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        seeds=(0, 1000, 2000),
        n_rounds=4,
        eval_every=2,
    )
    cfg = POFLConfig(n_devices=8, n_scheduled=3, **cfg_kw)
    return run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )


# --------------------------------------------------------------------------
# 1-device mesh: bit-identical, any environment
# --------------------------------------------------------------------------


def test_one_device_mesh_bit_identical(setup):
    """CI-asserted acceptance: mesh of 1 device == unsharded, bit for bit."""
    unsharded = _sweep(setup, mesh=None)
    sharded = _sweep(setup, mesh=make_cell_mesh(1))
    _assert_records_equal(unsharded, sharded)


def test_mesh_int_shorthand_equals_mesh_object(setup):
    """``mesh=N`` is sugar for ``mesh=make_cell_mesh(N)`` — and both resolve
    to the same cached engine (same mesh identity)."""
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1), n_rounds=3)
    by_int = _sweep(setup, mesh=1, spec=spec)
    by_mesh = _sweep(setup, mesh=make_cell_mesh(1), spec=spec)
    _assert_records_equal(by_int, by_mesh)


def test_make_cell_mesh_validates_device_count():
    with pytest.raises(ValueError, match="devices"):
        make_cell_mesh(N_VISIBLE + 1)
    with pytest.raises(ValueError, match="devices"):
        make_cell_mesh(0)
    assert int(np.asarray(make_cell_mesh().devices).size) == N_VISIBLE


# --------------------------------------------------------------------------
# engine-cache keying + retrace guard
# --------------------------------------------------------------------------


def test_cache_keys_distinguish_meshed_engines(setup):
    """Meshed and unmeshed engines must not collide; equal meshes (same
    devices, same layout) must — two Mesh objects are one engine."""
    data, _, _ = setup
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    plain = cached_engine(_loss_fn, data, cfg)
    meshed = cached_engine(_loss_fn, data, cfg, mesh=make_cell_mesh(1))
    assert meshed is not plain
    # a *fresh* Mesh object over the same devices is the same engine
    assert cached_engine(_loss_fn, data, cfg, mesh=make_cell_mesh(1)) is meshed
    assert cached_engine(_loss_fn, data, cfg) is plain
    if N_VISIBLE >= 2:
        wider = cached_engine(_loss_fn, data, cfg, mesh=make_cell_mesh(2))
        assert wider is not meshed and wider is not plain


def test_repeat_sharded_call_zero_retraces(setup):
    """Acceptance: repeat sharded run_lattice calls hit the cached engine's
    lattice jit — zero new traces, pure cache hits."""
    data, params0, ev = setup
    mesh = make_cell_mesh(min(8, N_VISIBLE))
    spec = LatticeSpec(policies=("pofl",), seeds=(0, 1, 2), n_rounds=3)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)

    first = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )
    # the policy-fused lattice is ONE engine keyed by the FUSED_POLICY
    # sentinel, regardless of how many policies the spec names
    engine = cached_engine(
        _loss_fn, data, dataclasses.replace(cfg, policy=FUSED_POLICY),
        eval_fn=ev, mesh=mesh,
    )
    traces = engine.n_lattice_traces
    assert traces >= 1
    stats0 = engine_cache_stats()

    second = run_lattice(
        _loss_fn, data, params0, spec, base_cfg=cfg, eval_fn=ev, mesh=mesh
    )
    assert engine.n_lattice_traces == traces  # ZERO scan retraces
    assert engine_cache_stats()["misses"] == stats0["misses"]
    assert engine_cache_stats()["hits"] > stats0["hits"]
    _assert_records_equal(first, second)


# --------------------------------------------------------------------------
# real multi-device semantics (8 fake CPU devices in CI)
# --------------------------------------------------------------------------


@needs_8_devices
def test_eight_device_mesh_matches_unsharded(setup):
    """Full parity suite on 8 fake devices: per-policy grid of 2 noise × 3
    seeds = 6 cells padded to 8, compared field by field, dtype-exact."""
    unsharded = _sweep(setup, mesh=None)
    sharded = _sweep(setup, mesh=make_cell_mesh(8))
    _assert_records_equal(unsharded, sharded)


@needs_8_devices
@pytest.mark.parametrize("n_seeds", [1, 5, 8, 11])
def test_non_divisible_grids_roundtrip_padding(setup, n_seeds):
    """Cell counts below, equal to, and not dividing the mesh size all
    round-trip through pad/unpad with unchanged shapes, order, and values."""
    spec = LatticeSpec(
        policies=("pofl",),
        seeds=tuple(range(0, 1000 * n_seeds, 1000)),
        n_rounds=3,
    )
    unsharded = _sweep(setup, mesh=None, spec=spec)
    sharded = _sweep(setup, mesh=make_cell_mesh(8), spec=spec)
    assert sharded.e_com.shape == (1, 1, 1, 1, n_seeds, 3)
    _assert_records_equal(unsharded, sharded)


@needs_8_devices
def test_sharded_hetero_churn_lattice_finite(setup):
    """Scenario composition survives sharding: Dirichlet-sized shards under
    churn availability, sharded over 8 devices — finite records, clamped
    |S|, matches the unsharded run exactly."""
    _, params0, _ = setup
    key = jax.random.PRNGKey(1)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_dirichlet_sized(x, y, n_devices=8, beta=0.4, seed=0)
    spec = LatticeSpec(policies=("pofl", "importance"), seeds=(0, 1, 2), n_rounds=5)
    kw = dict(
        base_cfg=POFLConfig(n_devices=8, n_scheduled=3),
        scenario="churn",
        scenario_params={"p_depart": 0.3, "p_arrive": 0.2},
    )
    unsharded = run_lattice(_loss_fn, data, params0, spec, **kw)
    sharded = run_lattice(_loss_fn, data, params0, spec, mesh=8, **kw)
    _assert_records_equal(unsharded, sharded)
    assert np.isfinite(sharded.e_com).all()
    assert (sharded.n_scheduled <= 3).all() and sharded.n_scheduled.min() < 3
