"""Local multi-process launcher for ``jax.distributed`` lattice runs.

Spawns N coordinated worker processes ON THIS MACHINE — a shared coordinator
address on localhost, a distinct process id per worker, and a per-worker
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` fake CPU device pool —
so the multi-host lattice path (``repro.sim.multihost`` + ``run_lattice``
over a :func:`~repro.sim.multihost.make_global_cell_mesh`) runs end-to-end on
one CPU box. That makes multi-host a CI-testable code path instead of a
cluster-only one: tests/test_multihost_lattice.py drives this launcher via
``subprocess`` and asserts the 2-process × 4-fake-device lattice is
dtype-exact against the in-process single-host run of the same spec.

Worker contract (written into each child's environment — real multi-host
deployments export the same three variables per host instead):

    REPRO_DIST_COORDINATOR   host:port of process 0's coordination service
    REPRO_DIST_NUM_PROCESSES total process count
    REPRO_DIST_PROCESS_ID    this process's rank

Observability: the worker env copies the launcher's ``os.environ``, so a
``REPRO_OBS_DIR`` (``repro.obs``) set on the launcher is inherited by every
worker — each writes its own ``events-p<rank>of<count>-<pid>.jsonl`` into
the shared sink directory (the rank stamp comes from the same
``REPRO_DIST_*`` contract above), and ``python -m repro.obs.report <dir>``
summarizes the whole topology.

Usage (CPU CI / laptop):

    # built-in parity workload: 2 hosts × 4 fake devices, records → npz
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        --workload parity --out /tmp/records.npz

    # multihost throughput bench (benchmarks/run.py --hosts N calls this)
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        --workload bench --out /tmp/bench.json

    # any script that calls sim.initialize_distributed() itself
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        -- python examples/sim_lattice.py --distributed

Workers force ``JAX_PLATFORMS=cpu``: this launcher exists for the
fake-device CPU story; real accelerator pods bring their own process
launcher (SLURM/GKE) and only need the env contract above.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import socket
import subprocess
import sys

import numpy as np

from repro.sim.engine import RoundRecord
from repro.sim.multihost import (
    ENV_COORDINATOR,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
)

# the canonical per-round ARRAY record fields (one source: the engine's
# RoundRecord, minus the optional pytree subtrees `diag` and `eval` — the
# npz parity serialization and cross-process comparisons cover the flat
# arrays only; obs diagnostics travel through the REPRO_OBS_DIR JSONL sink
# and eval curves through the in-process LatticeRecords/run_with_history
# paths instead. np.savez would pickle a None subtree as an object array
# (unreadable under allow_pickle=False) and collapse a NamedTuple leaf.)
_RECORD_FIELDS = tuple(
    f for f in RoundRecord._fields if f not in ("diag", "eval")
)
_DEVICE_COUNT_FLAG = re.compile(r"--xla_force_host_platform_device_count=\S+\s*")


def find_free_port() -> int:
    """Bind-and-release a localhost TCP port for the coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclasses.dataclass
class WorkerResult:
    process_id: int
    returncode: int
    output: str  # merged stdout+stderr


def worker_env(
    coordinator: str,
    num_processes: int,
    process_id: int,
    devices_per_proc: int,
    base_env: dict | None = None,
) -> dict:
    """Environment for one spawned worker: the ``REPRO_DIST_*`` contract plus
    a fresh fake-device pool (any inherited device-count flag is stripped —
    the child's pool must be exactly ``devices_per_proc``) and import roots
    matching the parent (``repro``'s src dir + the parent cwd, so workload
    code resolves ``benchmarks``/``examples`` the way the parent would)."""
    env = dict(os.environ if base_env is None else base_env)
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    inherited = _DEVICE_COUNT_FLAG.sub("", env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_proc}"
        + (f" {inherited}" if inherited else "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    import repro

    # namespace-package-safe (repro has no __init__.py, so __file__ is None)
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    roots = [src_root, os.getcwd()]
    if env.get("PYTHONPATH"):
        roots.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(roots)
    return env


def spawn_local(
    worker_argv: list[str],
    n_procs: int = 2,
    devices_per_proc: int = 4,
    timeout: float = 900.0,
    base_env: dict | None = None,
) -> list[WorkerResult]:
    """Run ``worker_argv`` as ``n_procs`` coordinated local processes.

    Every worker gets the same argv and the per-rank env contract; the call
    blocks until all exit. ``timeout`` is one ABSOLUTE deadline for the whole
    topology (workers run concurrently, so a wedged barrier costs one
    timeout, not one per rank); stragglers past it are killed with their
    output preserved. Results come back in rank order; nothing is raised on
    failure — see :func:`run_workers` for the raising wrapper.
    """
    import tempfile
    import time

    coordinator = f"127.0.0.1:{find_free_port()}"
    # build every env BEFORE the first spawn: a partial spawn would orphan
    # rank 0 blocking forever on the coordination barrier for ranks that
    # were never started
    envs = [
        worker_env(coordinator, n_procs, pid, devices_per_proc, base_env)
        for pid in range(n_procs)
    ]
    # each worker streams into its own temp file, never a pipe: sequential
    # pipe draining would wedge the topology as soon as one rank fills the
    # 64KB pipe buffer while an earlier rank still runs (ranks block on
    # each other through collectives, so output must never backpressure)
    outs = [tempfile.TemporaryFile(mode="w+") for _ in envs]
    procs = [
        subprocess.Popen(
            worker_argv, env=env, stdout=f, stderr=subprocess.STDOUT, text=True,
        )
        for env, f in zip(envs, outs)
    ]
    deadline = time.monotonic() + timeout
    killed = set()
    try:
        for pid, proc in enumerate(procs):
            try:
                proc.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
                killed.add(pid)
    finally:
        for proc in procs:
            if proc.poll() is None:
                proc.kill()
                proc.wait()
    results = []
    for pid, (proc, f) in enumerate(zip(procs, outs)):
        f.seek(0)
        out = f.read()
        f.close()
        if pid in killed:
            out += f"\n[launcher] killed at the {timeout}s deadline"
        results.append(WorkerResult(pid, -9 if pid in killed else proc.returncode, out))
    return results


def run_workers(
    worker_argv: list[str],
    n_procs: int = 2,
    devices_per_proc: int = 4,
    timeout: float = 900.0,
) -> list[WorkerResult]:
    """:func:`spawn_local` that raises ``RuntimeError`` (with output tails)
    when any worker exits nonzero — the launcher must never report success
    over a half-failed topology."""
    results = spawn_local(worker_argv, n_procs, devices_per_proc, timeout)
    failed = [r for r in results if r.returncode != 0]
    if failed:
        tails = "\n".join(
            f"--- worker {r.process_id} (rc={r.returncode}) ---\n{r.output[-4000:]}"
            for r in failed
        )
        raise RuntimeError(
            f"{len(failed)}/{len(results)} distributed workers failed:\n{tails}"
        )
    return results


# --------------------------------------------------------------------------
# LatticeRecords ↔ npz (the parity harness compares across processes)
# --------------------------------------------------------------------------


def save_records(path: str, records, meta: dict) -> None:
    """Persist a ``LatticeRecords`` (+ run metadata) to one ``.npz``."""
    np.savez(
        path,
        __axes__=json.dumps(records.axes),
        __meta__=json.dumps(meta),
        eval_rounds=records.eval_rounds,
        **{f: getattr(records, f) for f in _RECORD_FIELDS},
    )


def load_records(path: str):
    """Inverse of :func:`save_records` → ``(LatticeRecords, meta)``."""
    from repro.sim.lattice import LatticeRecords

    with np.load(path) as z:
        axes = json.loads(str(z["__axes__"]))
        meta = json.loads(str(z["__meta__"]))
        records = LatticeRecords(
            axes=axes,
            eval_rounds=z["eval_rounds"],
            **{f: z[f] for f in _RECORD_FIELDS},
        )
    return records, meta


# --------------------------------------------------------------------------
# the parity workload — ONE task definition shared by the subprocess workers
# and the in-process reference run, so the harness compares like for like
# --------------------------------------------------------------------------


def _parity_loss_fn(params, x, y):
    import jax
    import jax.numpy as jnp

    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def parity_spec(n_rounds: int = 4):
    """The pinned 2-policy × 2-noise × 3-seed grid (6 cells per policy —
    deliberately NOT a multiple of the 8-device CI topology, so the parity
    run exercises dead-cell padding across the process boundary)."""
    from repro.sim.lattice import LatticeSpec

    return LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        alphas=(0.1,),
        seeds=(0, 1000, 2000),
        n_rounds=n_rounds,
        eval_every=2,
    )


def run_parity_lattice(mesh=None, n_rounds: int = 4):
    """Run the parity workload twice on one engine → ``(records, meta)``.

    The second call must re-trace nothing (``n_lattice_traces`` flat) — the
    acceptance retrace guard runs INSIDE the worker topology, where the
    trace is the expensive multi-process SPMD program. Since the
    policy-fused lattice, the whole multi-policy spec is ONE engine (the
    ``FUSED_POLICY`` cache sentinel), ONE trace, and ONE compile — and the
    ``fuse_policies=False`` per-policy fallback must reproduce its records
    bit for bit on the same topology (``fused_matches_fallback``), with the
    cell axis now spanning policies across the process boundary.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.core.pofl import POFLConfig
    from repro.data.partition import partition_noniid_shards
    from repro.data.synthetic import make_classification_dataset
    from repro.sim.engine import FUSED_POLICY, cached_engine
    from repro.sim.lattice import run_lattice

    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_noniid_shards(x, y, n_devices=8)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def eval_fn(p):
        logits = x[:200] @ p["w"] + p["b"]
        return (
            _parity_loss_fn(p, x[:200], y[:200]),
            jnp.mean(jnp.argmax(logits, -1) == y[:200]),
        )

    spec = parity_spec(n_rounds)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    kw = dict(base_cfg=cfg, eval_fn=eval_fn, mesh=mesh)
    records = run_lattice(_parity_loss_fn, data, params0, spec, **kw)

    def fused_engine():
        return cached_engine(
            _parity_loss_fn, data, _dc.replace(cfg, policy=FUSED_POLICY),
            eval_fn=eval_fn, mesh=mesh,
        )

    traces = fused_engine().n_lattice_traces
    n_compiles = fused_engine().n_compiles
    repeat = run_lattice(_parity_loss_fn, data, params0, spec, **kw)
    traces_after = fused_engine().n_lattice_traces
    repeat_exact = all(
        np.array_equal(getattr(records, f), getattr(repeat, f))
        for f in _RECORD_FIELDS
    )
    fallback = run_lattice(
        _parity_loss_fn, data, params0, spec, fuse_policies=False, **kw
    )
    fused_matches_fallback = all(
        np.array_equal(getattr(records, f), getattr(fallback, f))
        for f in _RECORD_FIELDS
    )
    meta = {
        "n_rounds": n_rounds,
        "traces_first": traces,
        "n_lattice_compiles": n_compiles,
        "retrace_delta": int(traces_after - traces),
        "repeat_exact": bool(repeat_exact),
        "fused_matches_fallback": bool(fused_matches_fallback),
    }
    return records, meta


# --------------------------------------------------------------------------
# worker entrypoints
# --------------------------------------------------------------------------


def _worker_parity(args) -> None:
    from repro.sim.compile_cache import enable_compile_cache
    from repro.sim.multihost import initialize_distributed, make_global_cell_mesh

    enable_compile_cache()  # REPRO_COMPILE_CACHE inherited from the launcher
    initialize_distributed()
    import jax

    # no ambient-mesh context needed: run_lattice places everything with
    # explicit NamedShardings (the `-- command` test runs the same lattice
    # with no mesh context at all)
    mesh = make_global_cell_mesh()
    records, meta = run_parity_lattice(mesh=mesh, n_rounds=args.n_rounds)
    meta.update(
        process_count=jax.process_count(),
        process_index=jax.process_index(),
        n_global_devices=len(jax.devices()),
        n_local_devices=len(jax.local_devices()),
    )
    print(f"[worker {jax.process_index()}] {meta}", flush=True)
    if jax.process_index() == 0 and args.out:
        save_records(args.out, records, meta)


def _worker_bench(args) -> None:
    import time

    from repro.sim.compile_cache import enable_compile_cache
    from repro.sim.multihost import initialize_distributed, make_global_cell_mesh

    enable_compile_cache()  # REPRO_COMPILE_CACHE inherited from the launcher
    initialize_distributed()
    import jax

    from benchmarks.common import bench_sweep  # parent cwd is on PYTHONPATH
    from repro.sim import engine_cache_stats

    mesh = make_global_cell_mesh()
    t0 = time.time()
    _, timings, cells = bench_sweep(
        backend=args.backend, mesh=mesh, n_rounds=args.n_rounds
    )
    cache = engine_cache_stats()
    payload = {
        "lattice_seconds": round(timings["cold_seconds"], 3),
        "steady_seconds": round(timings["steady_seconds"], 3),
        "compile_seconds": round(timings["compile_seconds"], 3),
        "n_compiles": timings["n_compiles"],
        "engine_cache_hits": cache["hits"],
        "engine_cache_misses": cache["misses"],
        "wall_seconds": round(time.time() - t0, 3),
        "cells": cells,
        "n_hosts": jax.process_count(),
        "mesh_devices": len(jax.devices()),
    }
    print(f"[worker {jax.process_index()}] bench {payload}", flush=True)
    if jax.process_index() == 0 and args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)


def run_bench(
    n_procs: int,
    devices_per_proc: int,
    backend: str = "jnp",
    n_rounds: int = 30,
    timeout: float = 1200.0,
) -> dict:
    """Spawn the bench workload across ``n_procs`` local hosts and return
    process 0's timing payload (used by ``benchmarks/run.py --hosts N``)."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "bench.json")
        run_workers(
            [
                sys.executable, "-m", "repro.launch.distributed", "--worker",
                "--workload", "bench", "--out", out,
                "--backend", backend, "--n-rounds", str(n_rounds),
            ],
            n_procs=n_procs,
            devices_per_proc=devices_per_proc,
            timeout=timeout,
        )
        with open(out) as f:
            return json.load(f)


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--" in argv:
        split = argv.index("--")
        argv, command = argv[:split], argv[split + 1:]
    else:
        command = None

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--procs", type=int, default=2, metavar="N",
                        help="number of coordinated local processes")
    parser.add_argument("--devices-per-proc", type=int, default=4, metavar="K",
                        help="fake CPU devices per process "
                        "(--xla_force_host_platform_device_count)")
    parser.add_argument("--workload", default="parity",
                        choices=("parity", "bench"),
                        help="built-in workload when no `-- command` is given")
    parser.add_argument("--out", default="",
                        help="worker-0 output path (npz for parity, json for bench)")
    parser.add_argument("--n-rounds", type=int, default=4)
    parser.add_argument("--backend", default="jnp")
    parser.add_argument("--timeout", type=float, default=900.0)
    parser.add_argument("--worker", action="store_true",
                        help=argparse.SUPPRESS)  # internal: run AS a worker
    args = parser.parse_args(argv)

    if args.worker:
        if args.workload == "parity":
            _worker_parity(args)
        else:
            _worker_bench(args)
        return

    if args.procs < 1:
        parser.error("--procs must be >= 1")
    if args.devices_per_proc < 1:
        parser.error("--devices-per-proc must be >= 1")

    worker_argv = command or [
        sys.executable, "-m", "repro.launch.distributed", "--worker",
        "--workload", args.workload, "--out", args.out,
        "--n-rounds", str(args.n_rounds), "--backend", args.backend,
    ]
    results = run_workers(
        worker_argv,
        n_procs=args.procs,
        devices_per_proc=args.devices_per_proc,
        timeout=args.timeout,
    )
    sys.stdout.write(results[0].output)


if __name__ == "__main__":
    main()
