"""Local multi-process launcher for ``jax.distributed`` lattice runs.

Spawns N coordinated worker processes ON THIS MACHINE — a shared coordinator
address on localhost, a distinct process id per worker, and a per-worker
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` fake CPU device pool —
so the multi-host lattice path (``repro.sim.multihost`` + ``run_lattice``
over a :func:`~repro.sim.multihost.make_global_cell_mesh`) runs end-to-end on
one CPU box. That makes multi-host a CI-testable code path instead of a
cluster-only one: tests/test_multihost_lattice.py drives this launcher via
``subprocess`` and asserts the 2-process × 4-fake-device lattice is
dtype-exact against the in-process single-host run of the same spec.

Worker contract (written into each child's environment — real multi-host
deployments export the same three variables per host instead):

    REPRO_DIST_COORDINATOR   host:port of process 0's coordination service
    REPRO_DIST_NUM_PROCESSES total process count
    REPRO_DIST_PROCESS_ID    this process's rank

Observability: the worker env copies the launcher's ``os.environ``, so a
``REPRO_OBS_DIR`` (``repro.obs``) set on the launcher is inherited by every
worker — each writes its own ``events-p<rank>of<count>-<pid>.jsonl`` into
the shared sink directory (the rank stamp comes from the same
``REPRO_DIST_*`` contract above), and ``python -m repro.obs.report <dir>``
summarizes the whole topology.

Usage (CPU CI / laptop):

    # built-in parity workload: 2 hosts × 4 fake devices, records → npz
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        --workload parity --out /tmp/records.npz

    # multihost throughput bench (benchmarks/run.py --hosts N calls this)
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        --workload bench --out /tmp/bench.json

    # any script that calls sim.initialize_distributed() itself
    python -m repro.launch.distributed --procs 2 --devices-per-proc 4 \\
        -- python examples/sim_lattice.py --distributed

Workers force ``JAX_PLATFORMS=cpu``: this launcher exists for the
fake-device CPU story; real accelerator pods bring their own process
launcher (SLURM/GKE) and only need the env contract above.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import socket
import subprocess
import sys

import numpy as np

from repro.sim.engine import RoundRecord
from repro.sim.multihost import (
    ENV_COORDINATOR,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
)

# the canonical per-round ARRAY record fields (one source: the engine's
# RoundRecord, minus the optional pytree subtrees `diag`, `eval` and
# `health` — the npz parity serialization and cross-process comparisons
# cover the flat arrays only; obs diagnostics travel through the
# REPRO_OBS_DIR JSONL sink and eval curves through the in-process
# LatticeRecords/run_with_history paths instead. np.savez would pickle a
# None subtree as an object array (unreadable under allow_pickle=False)
# and collapse a NamedTuple leaf.)
_RECORD_FIELDS = tuple(
    f for f in RoundRecord._fields if f not in ("diag", "eval", "health")
)
_DEVICE_COUNT_FLAG = re.compile(r"--xla_force_host_platform_device_count=\S+\s*")


def find_free_port() -> int:
    """Bind-and-release a localhost TCP port for the coordinator."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclasses.dataclass
class WorkerResult:
    process_id: int
    returncode: int
    output: str  # merged stdout+stderr


def worker_env(
    coordinator: str,
    num_processes: int,
    process_id: int,
    devices_per_proc: int,
    base_env: dict | None = None,
) -> dict:
    """Environment for one spawned worker: the ``REPRO_DIST_*`` contract plus
    a fresh fake-device pool (any inherited device-count flag is stripped —
    the child's pool must be exactly ``devices_per_proc``) and import roots
    matching the parent (``repro``'s src dir + the parent cwd, so workload
    code resolves ``benchmarks``/``examples`` the way the parent would)."""
    env = dict(os.environ if base_env is None else base_env)
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    inherited = _DEVICE_COUNT_FLAG.sub("", env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_proc}"
        + (f" {inherited}" if inherited else "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    import repro

    # namespace-package-safe (repro has no __init__.py, so __file__ is None)
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    roots = [src_root, os.getcwd()]
    if env.get("PYTHONPATH"):
        roots.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(roots)
    return env


def spawn_local(
    worker_argv: list[str],
    n_procs: int = 2,
    devices_per_proc: int = 4,
    timeout: float = 900.0,
    base_env: dict | None = None,
) -> list[WorkerResult]:
    """Run ``worker_argv`` as ``n_procs`` coordinated local processes.

    Every worker gets the same argv and the per-rank env contract; the call
    blocks until all exit. ``timeout`` is one ABSOLUTE deadline for the whole
    topology (workers run concurrently, so a wedged barrier costs one
    timeout, not one per rank); stragglers past it are killed with their
    output preserved. Results come back in rank order; nothing is raised on
    failure — see :func:`run_workers` for the raising wrapper.
    """
    import tempfile
    import time

    coordinator = f"127.0.0.1:{find_free_port()}"
    # build every env BEFORE the first spawn: a partial spawn would orphan
    # rank 0 blocking forever on the coordination barrier for ranks that
    # were never started
    envs = [
        worker_env(coordinator, n_procs, pid, devices_per_proc, base_env)
        for pid in range(n_procs)
    ]
    # each worker streams into its own temp file, never a pipe: sequential
    # pipe draining would wedge the topology as soon as one rank fills the
    # 64KB pipe buffer while an earlier rank still runs (ranks block on
    # each other through collectives, so output must never backpressure)
    outs = [tempfile.TemporaryFile(mode="w+") for _ in envs]
    procs = [
        subprocess.Popen(
            worker_argv, env=env, stdout=f, stderr=subprocess.STDOUT, text=True,
        )
        for env, f in zip(envs, outs)
    ]
    deadline = time.monotonic() + timeout
    deadline_killed = set()
    try:
        for rank, proc in enumerate(procs):
            try:
                proc.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
                # a straggler can win the race and exit cleanly between the
                # timeout firing and the kill landing (kill on a reaped pid
                # is a no-op): only report a deadline kill when the recorded
                # returncode actually reflects one — never rewrite a real
                # exit status to -9
                if proc.returncode != 0:
                    deadline_killed.add(rank)
    finally:
        # ranks past the one that raised (or that an exception skipped) are
        # stragglers too: same kill, same bookkeeping
        for rank, proc in enumerate(procs):
            if proc.poll() is None:
                proc.kill()
                proc.wait()
                deadline_killed.add(rank)
    results = []
    for rank, (proc, f) in enumerate(zip(procs, outs)):
        f.seek(0)
        out = f.read()
        f.close()
        rc = proc.returncode if proc.returncode is not None else -9
        if rank in deadline_killed:
            out += f"\n[launcher] killed at the {timeout}s deadline (rc={rc})"
        results.append(WorkerResult(rank, rc, out))
    return results


def run_workers(
    worker_argv: list[str],
    n_procs: int = 2,
    devices_per_proc: int = 4,
    timeout: float = 900.0,
) -> list[WorkerResult]:
    """:func:`spawn_local` that raises ``RuntimeError`` (with output tails)
    when any worker exits nonzero — the launcher must never report success
    over a half-failed topology."""
    results = spawn_local(worker_argv, n_procs, devices_per_proc, timeout)
    failed = [r for r in results if r.returncode != 0]
    if failed:
        tails = "\n".join(
            f"--- worker {r.process_id} (rc={r.returncode}) ---\n{r.output[-4000:]}"
            for r in failed
        )
        raise RuntimeError(
            f"{len(failed)}/{len(results)} distributed workers failed:\n{tails}"
        )
    return results


# --------------------------------------------------------------------------
# supervised workers: per-rank restart with capped exponential backoff
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Per-rank supervision policy for :func:`supervise_workers`.

    ``max_restarts`` bounds restarts PER RANK (so one flapping rank cannot
    consume the whole budget of a healthy cohort); restart ``i`` waits
    ``min(backoff_base * 2**(i-1), backoff_cap)`` seconds first.
    ``liveness_timeout`` (seconds; None disables) declares a silent rank
    dead when its obs event files under the shared ``REPRO_OBS_DIR`` go
    that long without an mtime update — the chunked resilient workload
    heartbeats once per checkpoint chunk, so a wedged rank is killed and
    restarted instead of holding the topology to the absolute deadline."""

    max_restarts: int = 2
    backoff_base: float = 0.25
    backoff_cap: float = 8.0
    liveness_timeout: float | None = None
    poll_interval: float = 0.2


def supervise_workers(
    worker_argv: list[str],
    n_procs: int = 2,
    devices_per_proc: int = 1,
    timeout: float = 900.0,
    supervisor: SupervisorConfig | None = None,
    base_env: dict | None = None,
) -> list[WorkerResult]:
    """Run ``worker_argv`` as ``n_procs`` INDEPENDENT local workers, each
    under per-rank supervision: a rank that exits nonzero (crash, injected
    ``REPRO_FAULT_KILL``) or goes heartbeat-silent is restarted with capped
    exponential backoff, up to ``max_restarts`` times, and resumes from its
    own checkpoints. Replaces :func:`spawn_local`'s single absolute deadline
    for workloads that can re-enter (the deadline still exists as the outer
    backstop).

    UNLIKE :func:`spawn_local`, workers here must not rely on each other
    (no ``jax.distributed`` collectives): one rank is restarted alone while
    the others keep running, which would wedge a collective. The resilient
    lattice workload shards the fused cell grid into independent slices for
    exactly this reason.

    ``REPRO_FAULT_*`` is stripped from every RESTARTED rank's environment —
    injected faults are one-shot, so a supervised run recovers from the
    fault instead of re-firing it forever.

    Raises ``RuntimeError`` with per-rank output tails when any rank's
    restart budget is exhausted (or the absolute deadline fires); returns
    rank-ordered :class:`WorkerResult`\\ s (final attempt's rc/output,
    supervisor markers inline) on success.
    """
    import glob as _glob
    import tempfile
    import time

    from repro.obs.sink import emit, obs_dir
    from repro.sim.resilience import FAULT_ENV_VARS

    sup = supervisor or SupervisorConfig()
    coordinator = f"127.0.0.1:{find_free_port()}"
    sink = obs_dir() if base_env is None else (base_env.get("REPRO_OBS_DIR") or None)

    outs = [tempfile.TemporaryFile(mode="w+") for _ in range(n_procs)]
    procs: list[subprocess.Popen | None] = [None] * n_procs
    attempts = [0] * n_procs
    next_start = [0.0] * n_procs  # monotonic time before which a rank waits
    started_wall = [0.0] * n_procs
    done: list[WorkerResult | None] = [None] * n_procs
    deadline = time.monotonic() + timeout

    def note(rank: int, text: str) -> None:
        f = outs[rank]
        f.flush()
        f.seek(0, os.SEEK_END)  # the child shares the fd; never rewind it
        f.write(f"[supervisor] {text}\n")
        f.flush()

    def start(rank: int) -> None:
        env = worker_env(coordinator, n_procs, rank, devices_per_proc, base_env)
        if attempts[rank] > 0:
            for var in FAULT_ENV_VARS:  # injected faults are one-shot
                env.pop(var, None)
        note(rank, f"start rank {rank} attempt {attempts[rank]}")
        outs[rank].seek(0, os.SEEK_END)
        procs[rank] = subprocess.Popen(
            worker_argv, env=env,
            stdout=outs[rank], stderr=subprocess.STDOUT, text=True,
        )
        started_wall[rank] = time.time()

    def collect(rank: int) -> str:
        f = outs[rank]
        f.flush()
        f.seek(0)
        return f.read()

    def last_signal(rank: int) -> float:
        """Wall time of the rank's latest sign of life: its newest obs
        event-file mtime, floored at this attempt's start."""
        sig = started_wall[rank]
        if sink:
            pattern = os.path.join(
                sink, f"events-p{rank:03d}of{n_procs:03d}-*.jsonl"
            )
            for p in _glob.glob(pattern):
                try:
                    sig = max(sig, os.path.getmtime(p))
                except OSError:
                    pass
        return sig

    def on_crash(rank: int, rc: int, why: str) -> None:
        procs[rank] = None
        if attempts[rank] >= sup.max_restarts:
            note(rank, f"rank {rank} {why} (rc={rc}); restart budget "
                       f"({sup.max_restarts}) exhausted")
            done[rank] = WorkerResult(rank, rc if rc != 0 else 1, collect(rank))
            return
        attempts[rank] += 1
        delay = min(sup.backoff_base * 2 ** (attempts[rank] - 1), sup.backoff_cap)
        next_start[rank] = time.monotonic() + delay
        note(rank, f"rank {rank} {why} (rc={rc}); restart "
                   f"{attempts[rank]}/{sup.max_restarts} in {delay:.2f}s")
        emit(
            "supervisor", "supervisor.restart",
            rank=rank, rc=rc, attempt=attempts[rank], backoff=delay, why=why,
        )

    try:
        while any(d is None for d in done):
            now = time.monotonic()
            if now > deadline:
                for rank, proc in enumerate(procs):
                    if proc is not None and proc.poll() is None:
                        proc.kill()
                        proc.wait()
                    if done[rank] is None:
                        note(rank, f"killed at the {timeout}s deadline")
                        done[rank] = WorkerResult(rank, -9, collect(rank))
                break
            for rank in range(n_procs):
                if done[rank] is not None:
                    continue
                proc = procs[rank]
                if proc is None:
                    if now >= next_start[rank]:
                        start(rank)
                    continue
                rc = proc.poll()
                if rc is None:
                    if (
                        sup.liveness_timeout is not None
                        and time.time() - last_signal(rank) > sup.liveness_timeout
                    ):
                        proc.kill()
                        proc.wait()
                        on_crash(rank, proc.returncode, "went silent")
                    continue
                if rc == 0:
                    done[rank] = WorkerResult(rank, 0, collect(rank))
                else:
                    on_crash(rank, rc, "crashed")
            if any(d is None for d in done):
                time.sleep(sup.poll_interval)
    finally:
        for proc in procs:
            if proc is not None and proc.poll() is None:
                proc.kill()
                proc.wait()
        for f in outs:
            f.close()

    results = [d for d in done if d is not None]
    failed = [r for r in results if r.returncode != 0]
    if failed:
        tails = "\n".join(
            f"--- worker {r.process_id} (rc={r.returncode}) ---\n{r.output[-4000:]}"
            for r in failed
        )
        raise RuntimeError(
            f"{len(failed)}/{len(results)} supervised workers failed "
            f"(restart budget {sup.max_restarts}/rank):\n{tails}"
        )
    return results


# --------------------------------------------------------------------------
# LatticeRecords ↔ npz (the parity harness compares across processes)
# --------------------------------------------------------------------------


def save_records(path: str, records, meta: dict) -> None:
    """Persist a ``LatticeRecords`` (+ run metadata) to one ``.npz``."""
    np.savez(
        path,
        __axes__=json.dumps(records.axes),
        __meta__=json.dumps(meta),
        eval_rounds=records.eval_rounds,
        **{f: getattr(records, f) for f in _RECORD_FIELDS},
    )


def load_records(path: str):
    """Inverse of :func:`save_records` → ``(LatticeRecords, meta)``."""
    from repro.sim.lattice import LatticeRecords

    with np.load(path) as z:
        axes = json.loads(str(z["__axes__"]))
        meta = json.loads(str(z["__meta__"]))
        records = LatticeRecords(
            axes=axes,
            eval_rounds=z["eval_rounds"],
            **{f: z[f] for f in _RECORD_FIELDS},
        )
    return records, meta


# --------------------------------------------------------------------------
# the parity workload — ONE task definition shared by the subprocess workers
# and the in-process reference run, so the harness compares like for like
# --------------------------------------------------------------------------


def _parity_loss_fn(params, x, y):
    import jax
    import jax.numpy as jnp

    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def parity_spec(n_rounds: int = 4):
    """The pinned 2-policy × 2-noise × 3-seed grid (6 cells per policy —
    deliberately NOT a multiple of the 8-device CI topology, so the parity
    run exercises dead-cell padding across the process boundary)."""
    from repro.sim.lattice import LatticeSpec

    return LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11, 1e-9),
        alphas=(0.1,),
        seeds=(0, 1000, 2000),
        n_rounds=n_rounds,
        eval_every=2,
    )


def run_parity_lattice(mesh=None, n_rounds: int = 4):
    """Run the parity workload twice on one engine → ``(records, meta)``.

    The second call must re-trace nothing (``n_lattice_traces`` flat) — the
    acceptance retrace guard runs INSIDE the worker topology, where the
    trace is the expensive multi-process SPMD program. Since the
    policy-fused lattice, the whole multi-policy spec is ONE engine (the
    ``FUSED_POLICY`` cache sentinel), ONE trace, and ONE compile — and the
    ``fuse_policies=False`` per-policy fallback must reproduce its records
    bit for bit on the same topology (``fused_matches_fallback``), with the
    cell axis now spanning policies across the process boundary.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.core.pofl import POFLConfig
    from repro.data.partition import partition_noniid_shards
    from repro.data.synthetic import make_classification_dataset
    from repro.sim.engine import FUSED_POLICY, cached_engine
    from repro.sim.lattice import run_lattice

    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 640, key)
    data = partition_noniid_shards(x, y, n_devices=8)
    params0 = {"w": jnp.zeros((784, 10)), "b": jnp.zeros((10,))}

    def eval_fn(p):
        logits = x[:200] @ p["w"] + p["b"]
        return (
            _parity_loss_fn(p, x[:200], y[:200]),
            jnp.mean(jnp.argmax(logits, -1) == y[:200]),
        )

    spec = parity_spec(n_rounds)
    cfg = POFLConfig(n_devices=8, n_scheduled=3)
    kw = dict(base_cfg=cfg, eval_fn=eval_fn, mesh=mesh)
    records = run_lattice(_parity_loss_fn, data, params0, spec, **kw)

    def fused_engine():
        return cached_engine(
            _parity_loss_fn, data, _dc.replace(cfg, policy=FUSED_POLICY),
            eval_fn=eval_fn, mesh=mesh,
        )

    traces = fused_engine().n_lattice_traces
    n_compiles = fused_engine().n_compiles
    repeat = run_lattice(_parity_loss_fn, data, params0, spec, **kw)
    traces_after = fused_engine().n_lattice_traces
    repeat_exact = all(
        np.array_equal(getattr(records, f), getattr(repeat, f))
        for f in _RECORD_FIELDS
    )
    fallback = run_lattice(
        _parity_loss_fn, data, params0, spec, fuse_policies=False, **kw
    )
    fused_matches_fallback = all(
        np.array_equal(getattr(records, f), getattr(fallback, f))
        for f in _RECORD_FIELDS
    )
    meta = {
        "n_rounds": n_rounds,
        "traces_first": traces,
        "n_lattice_compiles": n_compiles,
        "retrace_delta": int(traces_after - traces),
        "repeat_exact": bool(repeat_exact),
        "fused_matches_fallback": bool(fused_matches_fallback),
    }
    return records, meta


# --------------------------------------------------------------------------
# worker entrypoints
# --------------------------------------------------------------------------


def _worker_parity(args) -> None:
    from repro.sim.compile_cache import enable_compile_cache
    from repro.sim.multihost import initialize_distributed, make_global_cell_mesh

    enable_compile_cache()  # REPRO_COMPILE_CACHE inherited from the launcher
    initialize_distributed()
    import jax

    # no ambient-mesh context needed: run_lattice places everything with
    # explicit NamedShardings (the `-- command` test runs the same lattice
    # with no mesh context at all)
    mesh = make_global_cell_mesh()
    records, meta = run_parity_lattice(mesh=mesh, n_rounds=args.n_rounds)
    meta.update(
        process_count=jax.process_count(),
        process_index=jax.process_index(),
        n_global_devices=len(jax.devices()),
        n_local_devices=len(jax.local_devices()),
    )
    print(f"[worker {jax.process_index()}] {meta}", flush=True)
    if jax.process_index() == 0 and args.out:
        save_records(args.out, records, meta)


def _worker_bench(args) -> None:
    import time

    from repro.sim.compile_cache import enable_compile_cache
    from repro.sim.multihost import initialize_distributed, make_global_cell_mesh

    enable_compile_cache()  # REPRO_COMPILE_CACHE inherited from the launcher
    initialize_distributed()
    import jax

    from benchmarks.common import bench_sweep  # parent cwd is on PYTHONPATH
    from repro.sim import engine_cache_stats

    mesh = make_global_cell_mesh()
    t0 = time.time()
    _, timings, cells = bench_sweep(
        backend=args.backend, mesh=mesh, n_rounds=args.n_rounds
    )
    cache = engine_cache_stats()
    payload = {
        "lattice_seconds": round(timings["cold_seconds"], 3),
        "steady_seconds": round(timings["steady_seconds"], 3),
        "compile_seconds": round(timings["compile_seconds"], 3),
        "n_compiles": timings["n_compiles"],
        "engine_cache_hits": cache["hits"],
        "engine_cache_misses": cache["misses"],
        "wall_seconds": round(time.time() - t0, 3),
        "cells": cells,
        "n_hosts": jax.process_count(),
        "mesh_devices": len(jax.devices()),
    }
    print(f"[worker {jax.process_index()}] bench {payload}", flush=True)
    if jax.process_index() == 0 and args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)


# --------------------------------------------------------------------------
# the resilient workload — independent rank-sharded checkpointed sweep
# (the supervised counterpart of the parity workload: no collectives, so a
# crashed rank restarts alone and resumes from its own checkpoints)
# --------------------------------------------------------------------------


def resilient_spec(n_rounds: int = 6):
    """The pinned fault-injection grid: 2 policies × 2 seeds × 2 local
    algorithms (fedavg + the stateful feddyn, so a resumed carry includes
    ``AlgState``) over the churn scenario — 8 cells, split across ranks."""
    from repro.sim.lattice import LatticeSpec

    return LatticeSpec(
        policies=("pofl", "channel"),
        noise_powers=(1e-11,),
        alphas=(0.1,),
        seeds=(0, 1000),
        n_rounds=n_rounds,
        eval_every=2,
        algorithms=("fedavg", "feddyn"),
    )


def _resilient_task():
    """One small fixed task for every resilient worker: dirichlet_mixed
    non-iid partition (unequal true shard sizes ride in ``n_samples``)."""
    import jax
    import jax.numpy as jnp

    from repro.data.partition import partition_dirichlet_mixed
    from repro.data.synthetic import make_classification_dataset

    key = jax.random.PRNGKey(0)
    x, y = make_classification_dataset("mnist_like", 320, key, dim=16)
    data = partition_dirichlet_mixed(x, y, n_devices=8, seed=0)
    params0 = {"w": jnp.zeros((16, 10)), "b": jnp.zeros((10,))}
    return _parity_loss_fn, data, params0


def _worker_resilient(args) -> None:
    """Run THIS rank's shard of the resilient sweep (rank/count from the
    ``REPRO_DIST_*`` env), checkpointing every ``--checkpoint-every`` rounds
    under ``--checkpoint-dir`` and publishing ``shard-r<rank>.npz`` there.
    Independent per rank: never calls ``initialize_distributed``."""
    from repro.core.pofl import POFLConfig
    from repro.obs.sink import process_coords
    from repro.sim.resilience import fault_nan, run_worker_shard

    loss_fn, data, params0 = _resilient_task()
    spec = resilient_spec(args.n_rounds)
    cfg = POFLConfig(
        n_devices=8, n_scheduled=3,
        # quarantine only when a NaN fault is injected: the default run
        # keeps the zero-overhead propagate path
        on_nonfinite="skip" if fault_nan() is not None else "propagate",
    )
    rank, _ = process_coords()
    shard_out = os.path.join(args.checkpoint_dir, f"shard-r{rank}.npz")
    lo, hi = run_worker_shard(
        loss_fn, data, params0, spec, shard_out,
        args.checkpoint_dir, args.checkpoint_every,
        base_cfg=cfg, scenario="churn",
    )
    print(f"[worker {rank}] shard cells [{lo}, {hi}) -> {shard_out}", flush=True)


def run_resilient(
    n_procs: int,
    checkpoint_dir: str,
    out: str = "",
    n_rounds: int = 6,
    checkpoint_every: int = 2,
    timeout: float = 900.0,
    supervisor: SupervisorConfig | None = None,
):
    """Supervise the resilient workload across ``n_procs`` independent local
    workers, then merge their shards into one full-grid ``LatticeRecords``
    (written to ``out`` as npz when given). Survives injected/real rank
    crashes up to the per-rank restart budget."""
    from repro.sim.resilience import merge_shards

    os.makedirs(checkpoint_dir, exist_ok=True)
    supervise_workers(
        [
            sys.executable, "-m", "repro.launch.distributed", "--worker",
            "--workload", "resilient",
            "--n-rounds", str(n_rounds),
            "--checkpoint-dir", checkpoint_dir,
            "--checkpoint-every", str(checkpoint_every),
        ],
        n_procs=n_procs,
        devices_per_proc=1,
        timeout=timeout,
        supervisor=supervisor,
    )
    spec = resilient_spec(n_rounds)
    records = merge_shards(
        spec, [os.path.join(checkpoint_dir, f"shard-r{r}.npz")
               for r in range(n_procs)],
    )
    if out:
        save_records(out, records, {"n_rounds": n_rounds, "n_procs": n_procs,
                                    "workload": "resilient"})
    return records


def run_bench(
    n_procs: int,
    devices_per_proc: int,
    backend: str = "jnp",
    n_rounds: int = 30,
    timeout: float = 1200.0,
) -> dict:
    """Spawn the bench workload across ``n_procs`` local hosts and return
    process 0's timing payload (used by ``benchmarks/run.py --hosts N``)."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "bench.json")
        run_workers(
            [
                sys.executable, "-m", "repro.launch.distributed", "--worker",
                "--workload", "bench", "--out", out,
                "--backend", backend, "--n-rounds", str(n_rounds),
            ],
            n_procs=n_procs,
            devices_per_proc=devices_per_proc,
            timeout=timeout,
        )
        with open(out) as f:
            return json.load(f)


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--" in argv:
        split = argv.index("--")
        argv, command = argv[:split], argv[split + 1:]
    else:
        command = None

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--procs", type=int, default=2, metavar="N",
                        help="number of coordinated local processes")
    parser.add_argument("--devices-per-proc", type=int, default=4, metavar="K",
                        help="fake CPU devices per process "
                        "(--xla_force_host_platform_device_count)")
    parser.add_argument("--workload", default="parity",
                        choices=("parity", "bench", "resilient"),
                        help="built-in workload when no `-- command` is given")
    parser.add_argument("--out", default="",
                        help="worker-0 output path (npz for parity, json for bench)")
    parser.add_argument("--n-rounds", type=int, default=4)
    parser.add_argument("--backend", default="jnp")
    parser.add_argument("--timeout", type=float, default=900.0)
    parser.add_argument("--checkpoint-dir", default="",
                        help="resilient workload: checkpoint/shard directory "
                        "(default: a temp dir)")
    parser.add_argument("--checkpoint-every", type=int, default=2,
                        help="resilient workload: rounds per checkpoint chunk")
    parser.add_argument("--max-restarts", type=int, default=2,
                        help="supervisor: restart budget per rank")
    parser.add_argument("--liveness-timeout", type=float, default=None,
                        help="supervisor: seconds of heartbeat silence "
                        "(REPRO_OBS_DIR mtimes) before a rank is killed and "
                        "restarted")
    parser.add_argument("--worker", action="store_true",
                        help=argparse.SUPPRESS)  # internal: run AS a worker
    args = parser.parse_args(argv)

    if args.worker:
        if args.workload == "parity":
            _worker_parity(args)
        elif args.workload == "resilient":
            _worker_resilient(args)
        else:
            _worker_bench(args)
        return

    if args.procs < 1:
        parser.error("--procs must be >= 1")
    if args.devices_per_proc < 1:
        parser.error("--devices-per-proc must be >= 1")

    if args.workload == "resilient" and command is None:
        import tempfile

        ckpt_dir = args.checkpoint_dir or tempfile.mkdtemp(prefix="repro-ckpt-")
        records = run_resilient(
            n_procs=args.procs,
            checkpoint_dir=ckpt_dir,
            out=args.out,
            n_rounds=args.n_rounds,
            checkpoint_every=args.checkpoint_every,
            timeout=args.timeout,
            supervisor=SupervisorConfig(
                max_restarts=args.max_restarts,
                liveness_timeout=args.liveness_timeout,
            ),
        )
        print(f"[launcher] resilient sweep done: {records.e_com.shape} "
              f"(checkpoints under {ckpt_dir})")
        return

    worker_argv = command or [
        sys.executable, "-m", "repro.launch.distributed", "--worker",
        "--workload", args.workload, "--out", args.out,
        "--n-rounds", str(args.n_rounds), "--backend", args.backend,
    ]
    results = run_workers(
        worker_argv,
        n_procs=args.procs,
        devices_per_proc=args.devices_per_proc,
        timeout=args.timeout,
    )
    sys.stdout.write(results[0].output)


if __name__ == "__main__":
    main()
