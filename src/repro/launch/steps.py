"""Sharded step builders: train_step / prefill_step / serve_step / stats_step.

Each builder returns (jitted_fn, arg_structs, in_shardings, out_shardings)
so the same object serves the real driver (launch/train.py, launch/serve.py)
and the multi-pod dry-run (.lower(**structs).compile()).

PO-FL at production scale (DESIGN.md §5):
  * FL device = one (pod × data) slice; n_fl = |pod|·|data|.
  * The AirComp weighted superposition Σ_i c_i·g_i is realized as per-example
    loss weights c_dev(e)·n_fl — the global data-parallel mean gradient then
    *equals* the PO-FL aggregate (tested against the reference in
    tests/test_distributed.py).
  * Receiver noise (Eq. 16): ν·z added to every gradient leaf post-backward,
    ν = sqrt(V_g)/a computed host-side from the round's schedule/channel.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import batch_ways
from repro.launch.sharding import (
    activation_specs,
    batch_pspecs,
    cache_pspecs,
    moe_strategy,
    params_pspecs,
    to_shardings,
)
from repro.models import layers as Lyr
from repro.models import api
from repro.models.config import InputShape, ModelConfig
from repro.optim.optimizers import OptState, Optimizer


class StepBundle(NamedTuple):
    fn: object            # jitted function
    arg_structs: dict     # kwargs of ShapeDtypeStructs for .lower(**...)
    in_shardings: object
    out_shardings: object


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def params_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: api.model_init(cfg, jax.random.PRNGKey(0)))


def opt_structs(optimizer: Optimizer, p_structs):
    return jax.eval_shape(optimizer.init, p_structs)


def opt_pspecs(p_specs, o_structs):
    """Optimizer state mirrors parameter sharding (FSDP: mu/nu shard with p)."""
    mu = p_specs if o_structs.mu is not None else None
    nu = p_specs if o_structs.nu is not None else None
    return OptState(step=P(), mu=mu, nu=nu)



def _layer_param_shardings(p_specs, mesh, key: str):
    """Per-layer (leading layer dim stripped) NamedSharding tree for the
    scanned parameter stack ``key`` — installed as activation sharding so
    scan bodies can constrain their parameter slice (and its cotangent)."""
    if not isinstance(p_specs, dict) or key not in p_specs:
        return None
    def strip(spec):
        return NamedSharding(mesh, P(*tuple(spec)[1:]))
    return jax.tree.map(strip, p_specs[key], is_leaf=lambda x: isinstance(x, P))

# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------


def auto_microbatches(cfg: ModelConfig, shape: InputShape, mesh,
                      budget_gib: float = 4.0) -> int:
    """Gradient-accumulation factor: split the global batch until the
    remat-saved residual carries (n_layers · B·S·D · 2 bytes / chips) fit
    ``budget_gib`` per device. Powers of two; keeps ≥1 example per FL slice.

    Budget is calibrated for the TPU target (bf16 carries; 16 GiB HBM minus
    params/optimizer/transients). Microbatches multiply ALL weight-gradient
    and weight-gather collectives (§Perf iteration 7), so m must be as small
    as memory allows — the CPU dry-run's f32-upcast artifacts must NOT force
    m upward."""
    n_chips = mesh.devices.size
    n_fl = batch_ways(mesh)
    n_layers = cfg.n_layers + (
        cfg.encdec.n_enc_layers if cfg.encdec is not None else 0
    )
    act_gib = (
        n_layers * shape.global_batch * shape.seq_len * cfg.d_model * 2
        / n_chips / 2**30
    )
    m = 1
    while act_gib / m > budget_gib and shape.global_batch // (m * 2) >= n_fl:
        m *= 2
    return m


def build_train_step(
    cfg: ModelConfig,
    shape: InputShape,
    mesh,
    optimizer: Optimizer,
    dtype=jnp.bfloat16,
    remat: bool = True,
    aircomp_noise: bool = True,
    n_microbatches: int | None = None,
) -> StepBundle:
    n_fl = batch_ways(mesh)
    specs = configs.input_specs(cfg, shape, dtype)
    batch_struct = specs["batch"]
    b = batch_struct["tokens"].shape[0]
    assert b % n_fl == 0, (b, n_fl)
    n_micro = n_microbatches or auto_microbatches(cfg, shape, mesh)
    assert b % (n_micro * n_fl) == 0, (b, n_micro, n_fl)

    p_structs = params_structs(cfg)
    o_structs = opt_structs(optimizer, p_structs)
    p_specs = params_pspecs(p_structs, mesh, moe_strategy(cfg, shape, mesh))
    o_specs = opt_pspecs(p_specs, o_structs)
    b_specs = batch_pspecs(batch_struct, mesh)
    from repro.launch.sharding import _batched  # noqa: PLC0415

    # CE logits chunks MUST shard the vocab over "model" — replicated they
    # cost ~10 GB/device at 150k vocab (EXPERIMENTS.md §Perf iteration 1).
    logits_sh = _ns(mesh, P(_batched(b, mesh), None, "model"))

    act_sh = activation_specs(cfg, shape, mesh)
    for k_, n_ in (("layers", "layer_params"), ("enc_layers", "enc_layer_params")):
        lsh = _layer_param_shardings(p_specs, mesh, k_)
        if lsh is not None:
            act_sh[n_] = lsh

    def train_step(params, opt_state, batch, coeffs, noise_amp, noise_key):
        # per-example weights: examples of FL device d get c_d · n_fl so the
        # global mean gradient equals Σ_d c_d · g_d (the PO-FL aggregate).
        w = jnp.repeat(coeffs * n_fl, b // n_fl, total_repeat_length=b)

        def loss_fn(p, mb, mw):
            # mixed precision: master weights stay fp32 in the optimizer;
            # compute weights are cast ONCE here so the per-layer FSDP
            # all-gathers move bf16 (2×) — grads flow back through the cast
            # and arrive fp32 (§Perf iteration 5)
            p = jax.tree.map(
                lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, p
            )
            return api.model_loss(
                p, cfg, mb, dtype=dtype, remat=remat, loss_weights=mw,
                logits_sharding=logits_sh,
            )

        p_shardings = jax.tree.map(lambda s: _ns(mesh, s), p_specs,
                                   is_leaf=lambda x: isinstance(x, P))

        with Lyr.activation_shardings(**act_sh):
            if n_micro == 1:
                (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch, w
                )
                grads = jax.lax.with_sharding_constraint(grads, p_shardings)
            else:
                # gradient accumulation: interleave so every microbatch holds
                # b/(m·n_fl) examples of EVERY FL device (batch is laid out
                # FL-device-major) — the mean of microbatch gradients is then
                # exactly the full-batch PO-FL aggregate.
                def to_micro(x):
                    per = b // n_fl
                    x = x.reshape((n_fl, n_micro, per // n_micro) + x.shape[1:])
                    return jnp.moveaxis(x, 1, 0).reshape(
                        (n_micro, b // n_micro) + x.shape[3:]
                    )

                mbs = jax.tree.map(to_micro, batch)
                mws = to_micro(w)

                def mb_step(acc, inp):
                    mb, mw = inp
                    (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb, mw
                    )
                    # FSDP-shard the per-microbatch gradients BEFORE the
                    # accumulate: unconstrained, XLA keeps the accumulator
                    # replicated and emits full-tensor f32 all-reduces
                    # (9.9 GiB/layer at 123B — §Perf iteration 6)
                    g = jax.lax.with_sharding_constraint(g, p_shardings)
                    acc_g, acc_l, acc_a = acc
                    return (
                        jax.tree.map(jnp.add, acc_g, g),
                        acc_l + l, acc_a + a,
                    ), None

                zero_g = jax.lax.with_sharding_constraint(
                    jax.tree.map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), params
                    ),
                    p_shardings,
                )
                (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                    mb_step, (zero_g, jnp.zeros(()), jnp.zeros(())), (mbs, mws)
                )
                grads = jax.tree.map(lambda x: x / n_micro, g_sum)
                loss, aux = l_sum / n_micro, a_sum / n_micro

        if aircomp_noise:
            # Eq. 16 receiver noise: ν·z on the aggregated gradient
            leaves, treedef = jax.tree.flatten(grads)
            keys = jax.random.split(noise_key, len(leaves))
            leaves = [
                l + noise_amp.astype(l.dtype)
                * jax.random.normal(k, l.shape, l.dtype)
                for l, k in zip(leaves, keys)
            ]
            grads = jax.tree.unflatten(treedef, leaves)

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    arg_structs = dict(
        params=p_structs,
        opt_state=o_structs,
        batch=batch_struct,
        coeffs=jax.ShapeDtypeStruct((n_fl,), jnp.float32),
        noise_amp=jax.ShapeDtypeStruct((), jnp.float32),
        noise_key=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    in_sh = dict(
        params=to_shardings(p_specs, mesh),
        opt_state=jax.tree.map(
            lambda s: _ns(mesh, s), o_specs,
            is_leaf=lambda x: isinstance(x, P),
        ),
        batch=to_shardings(b_specs, mesh),
        coeffs=_ns(mesh, P()),
        noise_amp=_ns(mesh, P()),
        noise_key=_ns(mesh, P()),
    )
    out_sh = (in_sh["params"], in_sh["opt_state"], _ns(mesh, P()))
    fn = jax.jit(
        train_step,
        in_shardings=tuple(in_sh.values()),
        out_shardings=out_sh,
        donate_argnums=(0, 1),
    )
    return StepBundle(fn, arg_structs, in_sh, out_sh)


# --------------------------------------------------------------------------
# per-device statistics (the Algorithm-1 "upload M_i, V_i, ||g_i||" pass)
# --------------------------------------------------------------------------


def build_stats_step(
    cfg: ModelConfig,
    shape: InputShape,
    mesh,
    dtype=jnp.bfloat16,
    n_probes: int = 4,
    remat: bool = True,
) -> StepBundle:
    """JVP-sketched per-FL-device gradient stats (core/sketch.py)."""
    from repro.core.sketch import sketch_device_stats

    n_fl = batch_ways(mesh)
    specs = configs.input_specs(cfg, shape, dtype)
    batch_struct = specs["batch"]
    b = batch_struct["tokens"].shape[0]

    p_structs = params_structs(cfg)
    p_specs = params_pspecs(p_structs, mesh, moe_strategy(cfg, shape, mesh))
    b_specs = batch_pspecs(batch_struct, mesh)
    from repro.launch.sharding import _batched  # noqa: PLC0415

    logits_sh = _ns(mesh, P(_batched(b, mesh), None, "model"))

    act_sh = activation_specs(cfg, shape, mesh)
    for k_, n_ in (("layers", "layer_params"), ("enc_layers", "enc_layer_params")):
        lsh = _layer_param_shardings(p_specs, mesh, k_)
        if lsh is not None:
            act_sh[n_] = lsh

    def stats_step(params, batch, key):
        def per_device_loss(p):
            per_ex, _ = api.model_loss(
                p, cfg, batch, dtype=dtype, remat=remat, reduce=False,
                logits_sharding=logits_sh,
            )
            return per_ex.reshape(n_fl, b // n_fl).mean(axis=1)

        with Lyr.activation_shardings(**act_sh):
            s = sketch_device_stats(per_device_loss, params, key, n_probes)
        return s.mean, s.var, s.norm

    arg_structs = dict(
        params=p_structs,
        batch=batch_struct,
        key=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    in_sh = dict(
        params=to_shardings(p_specs, mesh),
        batch=to_shardings(b_specs, mesh),
        key=_ns(mesh, P()),
    )
    out_sh = (_ns(mesh, P()),) * 3
    fn = jax.jit(
        stats_step, in_shardings=tuple(in_sh.values()), out_shardings=out_sh
    )
    return StepBundle(fn, arg_structs, in_sh, out_sh)


# --------------------------------------------------------------------------
# prefill / decode (serving)
# --------------------------------------------------------------------------


def build_prefill_step(
    cfg: ModelConfig, shape: InputShape, mesh, dtype=jnp.bfloat16
) -> StepBundle:
    specs = configs.input_specs(cfg, shape, dtype)
    batch_struct = specs["batch"]
    p_structs = params_structs(cfg)
    p_specs = params_pspecs(p_structs, mesh, moe_strategy(cfg, shape, mesh))
    b_specs = batch_pspecs(batch_struct, mesh)

    act_sh = activation_specs(cfg, shape, mesh)
    for k_, n_ in (("layers", "layer_params"), ("enc_layers", "enc_layer_params")):
        lsh = _layer_param_shardings(p_specs, mesh, k_)
        if lsh is not None:
            act_sh[n_] = lsh

    def prefill_step(params, batch):
        params = jax.tree.map(
            lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, params
        )
        with Lyr.activation_shardings(**act_sh):
            logits, cache = api.model_prefill(params, cfg, batch, dtype)
        return logits, cache

    # cache out-sharding from its eval_shape structure
    cache_struct = jax.eval_shape(
        lambda p, bt: api.model_prefill(p, cfg, bt, dtype)[1],
        p_structs, batch_struct,
    )
    c_specs = cache_pspecs(cache_struct, mesh)
    b_sz = batch_struct["tokens"].shape[0]
    from repro.launch.sharding import _batched  # noqa: PLC0415

    logits_spec = P(_batched(b_sz, mesh), None, "model")

    arg_structs = dict(params=p_structs, batch=batch_struct)
    in_sh = dict(
        params=to_shardings(p_specs, mesh), batch=to_shardings(b_specs, mesh)
    )
    out_sh = (_ns(mesh, logits_spec), to_shardings(c_specs, mesh))
    fn = jax.jit(
        prefill_step, in_shardings=tuple(in_sh.values()), out_shardings=out_sh
    )
    return StepBundle(fn, arg_structs, in_sh, out_sh)


def build_serve_step(
    cfg: ModelConfig, shape: InputShape, mesh, dtype=jnp.bfloat16
) -> StepBundle:
    """One decode step: one new token against a seq_len-deep KV/SSM cache."""
    specs = configs.input_specs(cfg, shape, dtype)
    token_struct, cache_struct, t_struct = (
        specs["token"], specs["cache"], specs["t"],
    )
    p_structs = params_structs(cfg)
    p_specs = params_pspecs(p_structs, mesh, moe_strategy(cfg, shape, mesh))
    c_specs = cache_pspecs(cache_struct, mesh)
    b = token_struct.shape[0]
    from repro.launch.sharding import _batched  # noqa: PLC0415

    tok_spec = P(_batched(b, mesh), None)

    act_sh = activation_specs(cfg, shape, mesh)  # moe_buffer only for decode

    def serve_step(params, token, cache, t):
        params = jax.tree.map(
            lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, params
        )
        with Lyr.activation_shardings(**act_sh):
            logits, new_cache = api.model_decode(params, cfg, token, cache, t, dtype)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return next_tok, new_cache

    arg_structs = dict(
        params=p_structs, token=token_struct, cache=cache_struct, t=t_struct
    )
    in_sh = dict(
        params=to_shardings(p_specs, mesh),
        token=_ns(mesh, tok_spec),
        cache=to_shardings(c_specs, mesh),
        t=_ns(mesh, P()),
    )
    out_sh = (_ns(mesh, tok_spec), to_shardings(c_specs, mesh))
    fn = jax.jit(
        serve_step,
        in_shardings=tuple(in_sh.values()),
        out_shardings=out_sh,
        donate_argnums=(2,),
    )
    return StepBundle(fn, arg_structs, in_sh, out_sh)


def build_step(
    cfg: ModelConfig, shape: InputShape, mesh, dtype=jnp.bfloat16,
    optimizer: Optimizer | None = None,
) -> StepBundle:
    """Dispatch on the shape kind: train / prefill / decode."""
    if shape.kind == "train":
        from repro.optim.optimizers import adamw

        return build_train_step(cfg, shape, mesh, optimizer or adamw(1e-4))
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, dtype)
    return build_serve_step(cfg, shape, mesh, dtype)
