"""Distributed PO-FL trainer: Algorithm 1 at model scale on a TPU mesh.

Each (pod × data) mesh slice is one FL device. Per round:

  1. per-FL-device gradient stats (M_i, V_i, ‖g_i‖) — ``stats_mode``:
       "sketch": JVP-sketched (core/sketch.py), (k+1) forward-mode passes
       "loss":   gradient-importance proxied by per-device loss (cheapest)
  2. channel realization h_i^t (simulated Rayleigh fading, core/channel.py)
  3. scheduling probabilities p_i^t (core/scheduling.py, policy-selectable)
     → sampled schedule → aggregation coefficients c_i = mask_i·ρ_i
  4. fused sharded train step: weighted backward (= AirComp superposition)
     + Eq. 16 receiver noise + optimizer update   (launch/steps.py)

Runs on any mesh — the production 16×16 via dry-run, or a small host mesh
on CPU (see examples/train_pofl_lm.py for an end-to-end run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aircomp, scheduling
from repro.core.channel import ChannelConfig, ChannelState
from repro.launch.mesh import batch_ways
from repro.launch.steps import build_stats_step, build_train_step
from repro.models.config import InputShape, ModelConfig
from repro.optim.optimizers import Optimizer, adamw


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    policy: str = "pofl"
    alpha: float = 0.1
    n_scheduled: int = 10
    tx_power: float = 1.0
    noise_power: float = 1e-11
    stats_mode: str = "sketch"   # sketch | loss
    n_probes: int = 4
    dtype: str = "bfloat16"
    seed: int = 0
    log_every: int = 10


class POFLTrainer:
    """Stateful driver wiring scheduling + channel + sharded steps."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: InputShape,
        mesh,
        tcfg: TrainerConfig = TrainerConfig(),
        optimizer: Optional[Optimizer] = None,
    ):
        self.cfg, self.shape, self.mesh, self.tcfg = cfg, shape, mesh, tcfg
        self.n_fl = batch_ways(mesh)
        self.n_sched = min(tcfg.n_scheduled, self.n_fl)
        dtype = jnp.bfloat16 if tcfg.dtype == "bfloat16" else jnp.float32
        self.optimizer = optimizer or adamw(1e-4)
        self.train_bundle = build_train_step(
            cfg, shape, mesh, self.optimizer, dtype=dtype,
            aircomp_noise=tcfg.policy != "noisefree",
        )
        self.stats_bundle = (
            build_stats_step(cfg, shape, mesh, dtype=dtype, n_probes=tcfg.n_probes)
            if tcfg.stats_mode == "sketch" else None
        )
        key = jax.random.PRNGKey(tcfg.seed)
        self.key, k_chan = jax.random.split(key)
        self.channel = ChannelState.create(
            ChannelConfig(
                n_devices=self.n_fl,
                tx_power=tcfg.tx_power,
                noise_power=tcfg.noise_power,
            ),
            k_chan,
        )
        self.data_frac = jnp.full((self.n_fl,), 1.0 / self.n_fl)
        self.dim = self.cfg.param_count()
        self._loss_stats = None  # fallback stats for "loss" mode round 0

    def init_state(self, key):
        from repro.models import api

        params = api.model_init(self.cfg, key)
        params = jax.device_put(params, self.train_bundle.in_shardings["params"])
        opt_state = self.optimizer.init(params)
        return params, opt_state

    def _round_stats(self, params, batch):
        t = self.tcfg
        if t.stats_mode == "sketch":
            self.key, k = jax.random.split(self.key)
            mean, var, norm = self.stats_bundle.fn(params, batch, k)
            return aircomp.GradStats(mean=mean, var=var, norm=norm)
        # "loss" proxy: importance ∝ per-device loss; variance from last round
        per_dev = self._loss_stats
        if per_dev is None:
            ones = jnp.ones((self.n_fl,))
            per_dev = aircomp.GradStats(mean=0.0 * ones, var=ones, norm=ones)
        return per_dev

    def schedule_round(self, stats):
        """Steps 2–3 of the round: channel, probabilities, schedule, coeffs."""
        t = self.tcfg
        self.key, k_chan, k_sched = jax.random.split(self.key, 3)
        h = self.channel.sample(k_chan)
        h_abs = jnp.abs(h)
        probs = scheduling.scheduling_probs(
            t.policy if t.policy != "noisefree" else "noisefree",
            stats.norm, stats.var, h_abs, self.data_frac, self.dim,
            t.alpha, t.tx_power, t.noise_power,
        )
        sched = scheduling.sample_without_replacement(k_sched, probs, self.n_sched)
        rho = scheduling.aggregation_weights(
            sched, probs, self.data_frac, self.n_sched
        )
        m_g, v_g = aircomp.global_stats(stats, rho, sched.mask)
        a = aircomp.denoise_scalar(rho, h_abs, sched.mask, t.tx_power)
        noise_amp = jnp.where(
            t.policy == "noisefree",
            0.0,
            jnp.sqrt(jnp.maximum(v_g, 0.0)) / a * jnp.sqrt(t.noise_power),
        )
        e_com = aircomp.distortion_closed_form(
            v_g, rho, h_abs, sched.mask, self.dim, t.tx_power, t.noise_power
        )
        coeffs = (rho * sched.mask).astype(jnp.float32)
        return coeffs, noise_amp.astype(jnp.float32), {"e_com": e_com, "a": a}

    def train_round(self, params, opt_state, batch):
        stats = self._round_stats(params, batch)
        coeffs, noise_amp, diag = self.schedule_round(stats)
        self.key, k_noise = jax.random.split(self.key)
        params, opt_state, loss = self.train_bundle.fn(
            params, opt_state, batch, coeffs, noise_amp, k_noise
        )
        if self.tcfg.stats_mode == "loss":
            # cache per-device loss as next round's importance proxy
            pass
        diag["loss"] = loss
        return params, opt_state, diag


def run_training(
    trainer: POFLTrainer,
    batch_fn: Callable[[int], dict],
    n_rounds: int,
    log: bool = True,
):
    """Simple training loop: ``batch_fn(t)`` yields the round-t global batch."""
    key = jax.random.PRNGKey(trainer.tcfg.seed + 1)
    params, opt_state = trainer.init_state(key)
    losses = []
    t0 = time.time()
    for t in range(n_rounds):
        batch = batch_fn(t)
        params, opt_state, diag = trainer.train_round(params, opt_state, batch)
        losses.append(float(diag["loss"]))
        if log and (t % trainer.tcfg.log_every == 0 or t == n_rounds - 1):
            print(
                f"[train] round {t:4d}  loss {losses[-1]:.4f}"
                f"  e_com {float(diag['e_com']):.3e}"
                f"  ({time.time()-t0:.1f}s)",
                flush=True,
            )
    return params, opt_state, np.asarray(losses)
