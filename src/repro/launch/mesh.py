"""Production mesh construction (TPU v5e pods).

Single-pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
pure data parallelism (its collectives cross the inter-pod DCI links).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS *before* the first jax
device query).

``activate_mesh`` is the version-compat shim for entering a mesh context:
the canonical spelling has moved across jax releases (``jax.set_mesh`` →
``jax.sharding.use_mesh`` → the ``Mesh`` object's own context manager), and
naming one of the newer APIs on an older jax raises AttributeError at call
time. Use the shim everywhere a mesh is activated.
"""
from __future__ import annotations

import jax


def activate_mesh(mesh):
    """Return a context manager that makes ``mesh`` the ambient mesh.

    Tries ``jax.set_mesh`` (newest), then ``jax.sharding.use_mesh``, then
    falls back to the ``Mesh`` context-manager protocol (``with mesh:``),
    which every supported jax version implements.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The mesh axes that carry (FL-device ×) batch parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_ways(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
