"""Production mesh construction (TPU v5e pods).

Single-pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
pure data parallelism (its collectives cross the inter-pod DCI links).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS *before* the first jax
device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The mesh axes that carry (FL-device ×) batch parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_ways(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
