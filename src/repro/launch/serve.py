"""Batched serving driver: prefill once, decode autoregressively.

The KV cache is sharded batch×("pod","data"), sequence×"model"
(flash-decoding style distributed attention — DESIGN.md §5); the decode loop
reuses one compiled serve_step with a donated cache.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import api
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig


class Server:
    def __init__(self, cfg: ModelConfig, shape: InputShape, mesh, dtype=jnp.bfloat16):
        self.cfg, self.shape, self.mesh, self.dtype = cfg, shape, mesh, dtype
        self.serve_bundle = build_serve_step(cfg, shape, mesh, dtype)

    def load_params(self, params):
        return jax.device_put(params, self.serve_bundle.in_shardings["params"])

    def decode(self, params, first_token, cache, start_t: int, n_tokens: int):
        """Greedy decode ``n_tokens`` tokens from a prefilled cache."""
        tok = first_token
        toks = [np.asarray(tok)]
        cache = jax.device_put(cache, self.serve_bundle.in_shardings["cache"])
        for i in range(n_tokens - 1):
            tok, cache = self.serve_bundle.fn(
                params, tok, cache, jnp.asarray(start_t + i, jnp.int32)
            )
            toks.append(np.asarray(tok))
        return np.concatenate(toks, axis=1), cache


def serve_demo(cfg: ModelConfig, mesh, batch: dict, n_tokens: int = 16,
               shape_name: str = "decode_32k", dtype=jnp.bfloat16, seed: int = 0):
    """End-to-end: init params → prefill → batched greedy decode."""
    shape = INPUT_SHAPES[shape_name]
    params = api.model_init(cfg, jax.random.PRNGKey(seed))
    t0 = time.time()
    logits, cache = api.model_prefill(params, cfg, batch, dtype)
    first = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    server = Server(cfg, shape, mesh, dtype)
    params = server.load_params(params)
    t0 = time.time()
    toks, _ = server.decode(
        params, first, cache, start_t=batch["tokens"].shape[1], n_tokens=n_tokens
    )
    t_decode = time.time() - t0
    return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                  "tok_per_s": n_tokens * toks.shape[0] / max(t_decode, 1e-9)}
