import os
if __name__ == "__main__":
    # MUST precede any other import (jax locks the device count at first
    # initialization): the dry-run needs 512 placeholder devices for the
    # production mesh. Guarded on __main__ so merely IMPORTING this module
    # (tests, benchmarks) never flips the ambient process to 512 devices —
    # smoke tests must see 1 device.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture × input shape × mesh) combination this lowers and
compiles the corresponding step (train_step for train shapes, prefill /
serve_step for inference shapes) against ShapeDtypeStruct inputs — no
allocation — and reports:

  * memory_analysis()  — per-device bytes (proves the config fits HBM)
  * cost_analysis()    — per-device HLO FLOPs / bytes (roofline inputs)
  * collective bytes   — parsed from the partitioned HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operands)

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] \
      --json out.json
"""
import argparse
import json
import re
import sys
import time

_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)"
)
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|branch_computations=\{|true_computation=|"
    r"false_computation=|computation=)(%[\w.\-]+)"
)


def _split_computations(hlo_text: str) -> dict:
    """{computation_name: [lines]} from HLO long text."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _wire_bytes(op: str, result: int, g: int) -> int:
    if op == "all-gather":
        return result * (g - 1) // g
    if op == "reduce-scatter":
        return result * (g - 1)
    if op == "all-reduce":
        return 2 * result * (g - 1) // g
    if op == "all-to-all":
        return result * (g - 1) // g
    return result  # collective-permute


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes of every collective in the partitioned HLO,
    MULTIPLIED by the trip counts of the while-loops enclosing it (XLA's
    text shows a loop body once; a collective inside the 88-layer scan
    executes 88×).

    Wire-byte convention (ring algorithm, group size g): all-gather
    (g-1)/g·result; reduce-scatter (g-1)·result; all-reduce 2(g-1)/g·result;
    all-to-all (g-1)/g·result; collective-permute result.

    Returns {op: {"count": static_op_count, "bytes": trip-weighted bytes}}.
    """
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"%toplevel": hlo_text.splitlines()}

    # loop structure: body computation -> trip count; parent -> children
    trip_of_body: dict = {}
    children: dict = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = [int(c) for c in _CONST_RE.findall(
                    "\n".join(comps.get(cond, []))
                )]
                trip_of_body[body] = max(consts) if consts else 1
                children.setdefault(name, []).append((body, trip_of_body[body]))
            for cm in _CALL_RE.finditer(line):
                children.setdefault(name, []).append((cm.group(1), 1))

    # effective multiplier per computation (entry = 1), DFS
    entry_lines = comps.get("__entry__")
    entry_name = next(
        (n for n, ls in comps.items() if n != "__entry__" and ls is entry_lines),
        None,
    )
    mult = {entry_name: 1}
    stack = [entry_name]
    seen = set()
    while stack:
        cur = stack.pop()
        if cur in seen or cur is None:
            continue
        seen.add(cur)
        for child, trips in children.get(cur, []):
            m_new = mult.get(cur, 1) * trips
            if m_new > mult.get(child, 0):
                mult[child] = m_new
                stack.append(child)

    out: dict = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        factor = mult.get(name, 1)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            shape_txt, op = m.group(1), m.group(2)
            result = sum(
                _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(shape_txt)
            )
            g = _group_size(line)
            rec = out.setdefault(op, {"count": 0, "bytes": 0})
            rec["count"] += 1
            rec["bytes"] += _wire_bytes(op, result, g) * factor
    return out


def cost_analysis_dict(cost) -> dict:
    """jax version compat: ``cost_analysis()`` returns a dict on newer jax
    but a (possibly empty) one-element list of dicts on older releases."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


class _UnrolledScans:
    """Monkeypatch jax.lax.scan to fully unroll — XLA cost analysis counts a
    while-loop body ONCE, so the scanned-layer build under-reports FLOPs by a
    factor of n_layers. The unrolled build is only LOWERED (never compiled):
    its pre-SPMD cost_analysis gives faithful whole-program FLOPs/bytes."""

    def __enter__(self):
        import jax as _jax

        self._orig = _jax.lax.scan

        def unrolled(f, init=None, xs=None, length=None, **kw):
            kw["unroll"] = True
            return self._orig(f, init, xs, length, **kw)

        _jax.lax.scan = unrolled
        return self

    def __exit__(self, *exc):
        import jax as _jax

        _jax.lax.scan = self._orig
        return False


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    import jax

    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step
    from repro.models.config import INPUT_SHAPES

    shape = INPUT_SHAPES[shape_name]
    if not configs.supports_shape(arch, shape):
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped",
            "reason": "pure full-attention arch — no long_500k variant (DESIGN §4)",
        }

    from repro.launch.mesh import activate_mesh

    cfg = configs.get_config(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with activate_mesh(mesh):
        bundle = build_step(cfg, shape, mesh)
        lowered = bundle.fn.lower(*bundle.arg_structs.values())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # faithful FLOP count: unrolled lowering (never compiled)
        t1 = time.time()
        with _UnrolledScans():
            bundle_u = build_step(cfg, shape, mesh)
            cost_u = cost_analysis_dict(
                bundle_u.fn.lower(*bundle_u.arg_structs.values()).cost_analysis()
            )
        t_unroll = time.time() - t1

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled.cost_analysis())
    coll = parse_collective_bytes(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            # donated args alias outputs; live set ≈ temps + max(arg, out)
            "peak_bytes": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + max(
                    getattr(mem, "argument_size_in_bytes", 0),
                    getattr(mem, "output_size_in_bytes", 0),
                )
            ),
        },
        "cost": {
            # per-device, scan bodies counted once (compiled, partitioned)
            "flops_per_device_scanned": float(cost.get("flops", -1)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
            # whole-program, unrolled, pre-SPMD (global; divide by chips)
            "flops_global": float(cost_u.get("flops", -1)),
            "bytes_accessed_global": float(cost_u.get("bytes accessed", -1)),
            "transcendentals_global": float(cost_u.get("transcendentals", -1)),
        },
        "collectives": coll,
        "collective_bytes_per_device": int(
            sum(v["bytes"] for v in coll.values())
        ),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "unroll_s": round(t_unroll, 1),
    }
    if verbose:
        print(
            f"[dryrun] {arch:>22s} × {shape_name:<12s} mesh={rec['mesh']:>8s}"
            f"  peak={rec['memory']['peak_bytes']/2**30:7.2f} GiB/dev"
            f"  flops={rec['cost']['flops_global']:.3e}"
            f"  coll={rec['collective_bytes_per_device']/2**20:9.1f} MiB/dev"
            f"  (lower {t_lower:.0f}s compile {t_compile:.0f}s unroll {t_unroll:.0f}s)",
            flush=True,
        )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args()

    from repro import configs
    from repro.models.config import INPUT_SHAPES

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 — report and continue
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    }
                    failures += 1
                    print(f"[dryrun] FAIL {arch} × {shape}: {rec['error']}",
                          flush=True)
                records.append(rec)
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
