"""Sharding rules: parameters (FSDP + tensor parallel), activations, caches.

Rules (DESIGN.md §5):
  * params: last dim divisible by |model| → "model" (tensor parallel);
    largest remaining dim divisible by |fsdp| → ("pod","data") (FSDP).
    Leaves under a scanned layer stack skip their leading layer dim.
  * activations/batches: batch dim over ("pod","data") when divisible.
  * KV caches: batch over ("pod","data"), *sequence* over "model"
    (flash-decoding style — uniform across archs regardless of kv_heads).
  * SSM state: batch over ("pod","data"), heads over "model".
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes, batch_ways
from repro.models.cache import AttnCache, EncDecCache, HybridCache, SSMCache

MIN_SHARD_SIZE = 4096  # don't bother sharding tiny leaves


def _fsdp_axes(mesh):
    return batch_axes(mesh)


def param_spec(shape, mesh, skip_leading: int = 0) -> P:
    spec: list = [None] * len(shape)
    dims = list(range(skip_leading, len(shape)))
    if not dims or int(np.prod([shape[d] for d in dims])) < MIN_SHARD_SIZE:
        return P(*spec)

    msize = mesh.shape["model"]
    fax = _fsdp_axes(mesh)
    fsize = batch_ways(mesh)

    # tensor-parallel: LAST eligible dim over "model"
    model_dim: Optional[int] = None
    for d in reversed(dims):
        if shape[d] % msize == 0 and shape[d] >= msize:
            spec[d] = "model"
            model_dim = d
            break

    # FSDP: largest remaining dim over ("pod","data") — meshes without those
    # axes (e.g. the sim lattice's ("cells", "model")) skip FSDP entirely
    cands = [
        d for d in dims
        if d != model_dim and shape[d] % fsize == 0 and shape[d] >= fsize
    ]
    if cands and fax:
        d = max(cands, key=lambda i: shape[i])
        spec[d] = fax if len(fax) > 1 else fax[0]
    return P(*spec)


_STACKED_KEYS = ("layers", "enc_layers")


def _is_stacked(path) -> bool:
    return any(
        getattr(k, "key", None) in _STACKED_KEYS for k in path
    )


def _is_moe(path) -> bool:
    return any(getattr(k, "key", None) == "moe" for k in path)


def _path_leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", "")))


def moe_strategy(cfg, shape, mesh) -> str | None:
    """"ep" (experts over "model") vs "dp" (groups over "model", expert
    weights gathered): EP's scatter/gather lowers to per-layer all-reduces
    of the FULL token tensor over the model axis (~23 GB/layer at olmoe
    train scale — §Perf iteration 8), so EP only pays off when the expert
    weights are larger than the dispatched token traffic (big experts or
    small token counts, e.g. decode)."""
    if cfg.moe is None:
        return None
    msize = mesh.shape["model"]
    moe = cfg.moe
    if moe.n_experts % msize:
        return "dp"
    if shape.kind == "train":
        # measured (§Perf iteration 8): in the backward pass DP-mode's
        # gathered expert weights interact with gradient accumulation far
        # worse than EP's token all-reduces — keep EP for training
        return "ep"
    n_tok = shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
    token_bytes = n_tok * moe.top_k * cfg.d_model * 4 * 2
    weight_bytes = 3 * moe.n_experts * cfg.d_model * moe.d_ff_expert * 2 * 3
    return "dp" if weight_bytes < token_bytes else "ep"


def params_pspecs(params_shape, mesh, moe_mode: str | None = "ep"):
    """PartitionSpec pytree for a params (shape) pytree.

    Special cases: the embedding table shards VOCAB over "model" and d_model
    over FSDP (and lm_head the transpose) — so the tied/untied output head
    contracts into vocab-sharded logits locally. The generic rule (last dim
    → "model") would instead produce a full-vocab (B, chunk, V) all-reduce
    over the model axis (~10 GB/device at 150k vocab; §Perf iteration 1).
    MoE expert weights follow ``moe_mode`` ("ep": E over "model"; "dp":
    model-replicated, FSDP on the largest dim — see moe_strategy).
    """
    msize = mesh.shape["model"]
    fax = _fsdp_axes(mesh)
    fsize = batch_ways(mesh)
    f_axes = fax if len(fax) > 1 else fax[0]

    def leaf_spec(path, leaf):
        name = _path_leaf_name(path)
        shape = leaf.shape
        if name == "embed" and len(shape) == 2:
            v_ok = shape[0] % msize == 0
            d_ok = shape[1] % fsize == 0
            return P("model" if v_ok else None, f_axes if d_ok else None)
        if name == "lm_head" and len(shape) == 2:
            d_ok = shape[0] % fsize == 0
            v_ok = shape[1] % msize == 0
            return P(f_axes if d_ok else None, "model" if v_ok else None)
        if (
            name in ("w_gate", "w_in", "w_out") and len(shape) == 4
            and _is_moe(path)
        ):
            # (L, E, D, F): "ep" → E over "model"; "dp" → model-replicated
            # (gathered per layer), FSDP on the bigger of D/F either way
            e_ok = moe_mode == "ep" and shape[1] % msize == 0
            d_dim = 2 if shape[2] >= shape[3] else 3
            spec = [None, "model" if e_ok else None, None, None]
            if shape[d_dim] % fsize == 0:
                spec[d_dim] = f_axes
            return P(*spec)
        return param_spec(shape, mesh, skip_leading=1 if _is_stacked(path) else 0)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def _batched(shape_b, mesh) -> P | tuple:
    """Batch-dim spec component: over ("pod","data") when divisible."""
    fax = _fsdp_axes(mesh)
    if shape_b % batch_ways(mesh) == 0:
        return fax if len(fax) > 1 else fax[0]
    # try data only
    if "data" in mesh.axis_names and shape_b % mesh.shape["data"] == 0:
        return "data"
    return None


def batch_pspecs(batch_struct, mesh):
    """Specs for a train/prefill batch dict {tokens, [embeds|frames]}."""

    def spec(leaf):
        bspec = _batched(leaf.shape[0], mesh)
        return P(bspec, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch_struct)


def _seq_spec(seq_len, mesh):
    msize = mesh.shape["model"]
    return "model" if (seq_len % msize == 0 and seq_len >= msize) else None


def cache_pspecs(cache_struct, mesh):
    """Specs for decode caches (AttnCache / SSMCache / Hybrid / EncDec)."""
    msize = mesh.shape["model"]

    def attn_specs(c: AttnCache):
        L, b, s, kv, dh = c.k.shape
        bs = _batched(b, mesh)
        ss = _seq_spec(s, mesh)
        kvspec = P(None, bs, ss, None, None)
        return AttnCache(k=kvspec, v=kvspec, pos=P(None))

    def ssm_specs(c: SSMCache):
        L, b, h, n, p = c.state.shape
        bs = _batched(b, mesh)
        hs = "model" if h % msize == 0 else None
        cch = c.conv.shape[-1]
        cs = "model" if cch % msize == 0 else None
        return SSMCache(
            state=P(None, bs, hs, None, None),
            conv=P(None, bs, None, cs),
        )

    if isinstance(cache_struct, HybridCache):
        return HybridCache(
            ssm=ssm_specs(cache_struct.ssm), attn=attn_specs(cache_struct.attn)
        )
    if isinstance(cache_struct, EncDecCache):
        L, b, s_enc, kv, dh = cache_struct.cross_k.shape
        bs = _batched(b, mesh)
        xs = P(None, bs, _seq_spec(s_enc, mesh), None, None)
        return EncDecCache(
            self_attn=attn_specs(cache_struct.self_attn), cross_k=xs, cross_v=xs
        )
    if isinstance(cache_struct, SSMCache):
        return ssm_specs(cache_struct)
    return attn_specs(cache_struct)


def activation_specs(cfg, shape, mesh) -> dict:
    """NamedShardings for the named activation cut-points (layers.constrain).

    residual: attention-family archs shard the SEQUENCE over "model"
    (Megatron-style sequence parallelism — remat-saved (B,S,D) carries
    otherwise replicate 16× over the model axis and blow HBM); SSM/hybrid
    archs shard d_model instead (the SSD chunk scan iterates the sequence).
    moe_buffer: expert dim over "model" (expert parallelism).
    Decode steps get only moe_buffer (S=1 has no sequence to shard).
    """
    from jax.sharding import NamedSharding

    msize = mesh.shape["model"]
    bt = _batched(shape.global_batch, mesh)
    out = {}
    if shape.kind in ("train", "prefill"):
        dspec = "model" if cfg.d_model % msize == 0 else None
        if cfg.arch_type in ("ssm", "hybrid"):
            out["residual"] = P(bt, None, dspec)
        else:
            sspec = "model" if shape.seq_len % msize == 0 else None
            out["residual"] = P(bt, sspec, None)
        out["ce_input"] = P(bt, None, dspec)
    if cfg.moe is not None:
        from repro.models.layers import _moe_group_size

        if shape.kind in ("train", "prefill"):
            n_tok = shape.global_batch * shape.seq_len
        else:
            n_tok = shape.global_batch
        gs = _moe_group_size(n_tok)
        n_groups = n_tok // gs
        ways = batch_ways(mesh)
        mode = moe_strategy(cfg, shape, mesh)
        if mode == "dp":
            # groups over (batch axes × "model") — dispatch fully local
            all_ax = tuple(a for a in mesh.axis_names)
            full = ways * msize
            if n_groups % full == 0 and n_groups >= full:
                gspec = all_ax
            elif n_groups % ways == 0 and n_groups >= ways:
                gspec = bt
            else:
                gspec = None
            out["moe_buffer"] = P(gspec, None, None, None)
        else:
            gspec = bt if (n_groups % ways == 0 and n_groups >= ways) else None
            espec = "model" if cfg.moe.n_experts % msize == 0 else None
            out["moe_buffer"] = P(gspec, espec, None, None)
    return {k: NamedSharding(mesh, v) for k, v in out.items()}


def to_shardings(pspecs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
