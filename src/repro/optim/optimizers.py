"""Minimal optax-free optimizer substrate (pytree-native, shardable).

Each optimizer is a (init, update) pair operating on pytrees; state tensors
mirror parameter shapes, so whatever sharding the params carry propagates to
the optimizer state under pjit (FSDP-compatible).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any    # first moment (or momentum); zeros-pytree
    nu: Any    # second moment; zeros-pytree (unused by sgd)


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def _zeros_like_tree(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr: Callable[[jnp.ndarray], jnp.ndarray] | float, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), mu=_zeros_like_tree(params), nu=None)

    def update(grads, state, params):
        eta = lr_fn(state.step)
        if momentum > 0.0:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        else:
            mu = grads
        new_params = jax.tree.map(lambda p, m: p - eta * m, params, mu)
        return new_params, OptState(step=state.step + 1, mu=mu if momentum > 0 else state.mu, nu=None)

    return Optimizer(init=init, update=update)


def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_zeros_like_tree(params),
            nu=_zeros_like_tree(params),
        )

    def update(grads, state, params):
        step = state.step + 1
        eta = lr_fn(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - eta * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def paper_decay_schedule(lr0: float, decay: float = 0.95, lr_min: float = 1e-5):
    """Paper Sec. V-A: η^t = max(η0 · 0.95^t, 1e-5)."""

    def fn(step):
        return jnp.maximum(lr0 * decay ** step.astype(jnp.float32), lr_min)

    return fn


def cosine_schedule(lr0: float, total_steps: int, warmup: int = 0, lr_min: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr0 * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = lr_min + 0.5 * (lr0 - lr_min) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn
