from repro.optim.optimizers import (
    OptState,
    adamw,
    cosine_schedule,
    paper_decay_schedule,
    sgd,
)

__all__ = ["OptState", "adamw", "sgd", "cosine_schedule", "paper_decay_schedule"]
