"""Seeded synthetic datasets (offline container — see DESIGN.md §7).

``mnist_like``: 784-dim, 10 classes — stands in for MNIST (logistic regression,
convex case). ``cifar_like``: 3×32×32, 10 classes — stands in for CIFAR-10
(CNN, non-convex case). Classes are Gaussian clusters around random prototype
directions with per-class structure so that (a) a linear model is learnable
but not trivially, and (b) non-IID label sharding produces genuinely
heterogeneous local gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_classification_dataset(
    kind: str,
    n_samples: int,
    key: jax.Array,
    n_classes: int = 10,
    noise: float = 0.8,
    proto_seed: int = 42,
    dim: int | None = None,
    channel_bias: float = 0.0,
):
    """Returns (features, labels) with features flattened for 'mnist_like'
    and shaped (n, 32, 32, 3) for 'cifar_like'.

    Class prototypes are fixed by ``proto_seed`` (NOT by ``key``) so that
    train/test splits drawn with different sample keys share one underlying
    distribution. ``dim`` overrides the flat feature dimension of
    ``mnist_like`` (the D-scaling benchmark axis; default 784 keeps every
    historical draw bit-identical); ``cifar_like``'s image shape is fixed.

    ``channel_bias`` (``cifar_like`` only) adds a per-class per-CHANNEL
    offset — broadcast over the spatial grid, fixed by ``proto_seed`` — so
    classes also differ in low-frequency color statistics, the way real
    image classes do. The per-pixel prototypes alone have near-zero spatial
    mean, which a global-average-pooling CNN cannot see until its conv
    stack has learned spatial features; the channel offset survives any
    spatial pooling, making the task learnable by such a CNN in few SGD
    steps. Default 0.0 skips the op entirely — every historical draw stays
    bit-identical.
    """
    if kind == "mnist_like":
        dim = 784 if dim is None else int(dim)
        shape = (dim,)
    elif kind == "cifar_like":
        if dim is not None:
            raise ValueError("dim override only supported for mnist_like")
        dim = 32 * 32 * 3
        shape = (32, 32, 3)
    else:
        raise ValueError(kind)

    _, k_label, k_noise, k_scale = jax.random.split(key, 4)
    k_proto = jax.random.PRNGKey(proto_seed)
    prototypes = jax.random.normal(k_proto, (n_classes, dim)) / jnp.sqrt(dim)
    labels = jax.random.randint(k_label, (n_samples,), 0, n_classes)
    eps = jax.random.normal(k_noise, (n_samples, dim)) / jnp.sqrt(dim)
    # per-sample scale variation (mimics stroke-thickness / luminance variety)
    scale = 1.0 + 0.3 * jax.random.normal(k_scale, (n_samples, 1))
    feats = scale * (prototypes[labels] + noise * eps)
    feats = feats.reshape((n_samples,) + shape)
    if channel_bias:
        if kind != "cifar_like":
            raise ValueError(
                "channel_bias is an image-channel feature (cifar_like only)"
            )
        k_bias = jax.random.split(k_proto)[1]
        bias = jax.random.normal(k_bias, (n_classes, shape[-1]))
        feats = feats + channel_bias * bias[labels][:, None, None, :]
    return feats.astype(jnp.float32), labels.astype(jnp.int32)


def pad_with_wrong_labels(features, labels, n_pad: int, n_classes: int = 10):
    """Append ``n_pad`` pad rows whose labels are deliberately WRONG.

    The pad rows cycle the real features (so they look like genuine inputs)
    but carry labels shifted by +1 mod ``n_classes`` — a model that predicts
    the true class gets every pad row "wrong". An eval that leaks pad rows
    into its accuracy therefore shifts measurably; one that honors the
    valid-prefix contract (``n_valid = len(labels)``) is unaffected. Test
    scaffolding for the padded-shard eval-masking regression.
    """
    feats = jnp.asarray(features)
    labs = jnp.asarray(labels)
    idx = jnp.arange(n_pad) % feats.shape[0]
    pad_feats = feats[idx]
    pad_labs = (labs[idx] + 1) % n_classes
    return (
        jnp.concatenate([feats, pad_feats], axis=0),
        jnp.concatenate([labs, pad_labs], axis=0),
    )


def make_token_dataset(
    n_sequences: int,
    seq_len: int,
    vocab_size: int,
    key: jax.Array,
    order: int = 2,
):
    """Synthetic LM corpus: a random order-``order`` Markov chain over a small
    effective vocabulary slice, so next-token prediction has learnable signal."""
    eff_vocab = min(vocab_size, 256)
    k_table, k_init, k_walk = jax.random.split(key, 3)
    # Sparse-ish transition logits
    table = jax.random.gumbel(k_table, (eff_vocab, eff_vocab))
    table = jnp.where(table > 1.0, table, -1e9)  # keep only likely transitions
    init = jax.random.randint(k_init, (n_sequences,), 0, eff_vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, table[tok])
        return nxt, nxt

    keys = jax.random.split(k_walk, seq_len - 1)

    def walk(tok0, i):
        _, seq = jax.lax.scan(step, tok0, jax.vmap(lambda k: jax.random.fold_in(k, i))(keys))
        return jnp.concatenate([tok0[None], seq])

    toks = jax.vmap(walk)(init, jnp.arange(n_sequences))
    return toks.astype(jnp.int32)
