"""Non-IID federated partitioning (paper Sec. V-A).

Sort-by-label sharding: sort the M training samples by label, split into
``n_devices * shards_per_device`` shards, assign each device
``shards_per_device`` random shards — each device then holds (about)
``shards_per_device`` classes. ``classes_per_device`` (paper's C) equals
``shards_per_device`` for balanced class counts.
"""
from __future__ import annotations

import numpy as np

from repro.core.pofl import DeviceData


def partition_noniid_shards(
    features,
    labels,
    n_devices: int,
    shards_per_device: int = 2,
    seed: int = 0,
) -> DeviceData:
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    n_shards = n_devices * shards_per_device
    shard_size = m_total // n_shards

    order = np.argsort(labels, kind="stable")
    rng = np.random.default_rng(seed)
    shard_ids = rng.permutation(n_shards)

    per_dev_feats, per_dev_labels = [], []
    for d in range(n_devices):
        idx = []
        for s in shard_ids[d * shards_per_device : (d + 1) * shards_per_device]:
            idx.append(order[s * shard_size : (s + 1) * shard_size])
        idx = np.concatenate(idx)
        rng.shuffle(idx)
        per_dev_feats.append(features[idx])
        per_dev_labels.append(labels[idx])

    return DeviceData(
        features=np.stack(per_dev_feats),
        labels=np.stack(per_dev_labels),
    )


def partition_iid(features, labels, n_devices: int, seed: int = 0) -> DeviceData:
    """IID control: uniformly random equal split."""
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    per = m_total // n_devices
    rng = np.random.default_rng(seed)
    perm = rng.permutation(m_total)[: per * n_devices].reshape(n_devices, per)
    return DeviceData(features=features[perm], labels=labels[perm])
