"""Non-IID federated partitioning (paper Sec. V-A + sim scenario presets).

Sort-by-label sharding: sort the M training samples by label, split into
``n_devices * shards_per_device`` shards, assign each device
``shards_per_device`` random shards — each device then holds (about)
``shards_per_device`` classes. ``classes_per_device`` (paper's C) equals
``shards_per_device`` for balanced class counts.

``partition_dirichlet`` adds the Dirichlet(β) label-skew partition standard
in the FL literature (Hsu et al. 2019), equalized to stacked per-device
shards so it plugs into the same ``DeviceData`` interface.
"""
from __future__ import annotations

import numpy as np

from repro.core.pofl import DeviceData


def partition_noniid_shards(
    features,
    labels,
    n_devices: int,
    shards_per_device: int = 2,
    seed: int = 0,
) -> DeviceData:
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    n_shards = n_devices * shards_per_device
    shard_size = m_total // n_shards

    order = np.argsort(labels, kind="stable")
    rng = np.random.default_rng(seed)
    shard_ids = rng.permutation(n_shards)

    per_dev_feats, per_dev_labels = [], []
    for d in range(n_devices):
        idx = []
        for s in shard_ids[d * shards_per_device : (d + 1) * shards_per_device]:
            idx.append(order[s * shard_size : (s + 1) * shard_size])
        idx = np.concatenate(idx)
        rng.shuffle(idx)
        per_dev_feats.append(features[idx])
        per_dev_labels.append(labels[idx])

    return DeviceData(
        features=np.stack(per_dev_feats),
        labels=np.stack(per_dev_labels),
    )


def partition_iid(features, labels, n_devices: int, seed: int = 0) -> DeviceData:
    """IID control: uniformly random equal split."""
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    per = m_total // n_devices
    rng = np.random.default_rng(seed)
    perm = rng.permutation(m_total)[: per * n_devices].reshape(n_devices, per)
    return DeviceData(features=features[perm], labels=labels[perm])


def partition_dirichlet(
    features,
    labels,
    n_devices: int,
    beta: float = 0.5,
    seed: int = 0,
) -> DeviceData:
    """Dirichlet(β) label-proportion partition, equalized to stacked shards.

    Device d's label distribution is q_d ~ Dir(β·1_K); its m = M//N samples
    are drawn class-by-class to match q_d from per-class pools, topping up
    from the leftover pool when a class runs dry (so shards stay equal-size
    and every sample is used at most once). β→0 gives near-single-class
    devices; β→∞ recovers the global label distribution.
    """
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    per = m_total // n_devices
    rng = np.random.default_rng(seed)

    classes = np.unique(labels)
    pools = {c: rng.permutation(np.flatnonzero(labels == c)).tolist() for c in classes}
    props = rng.dirichlet(np.full(len(classes), beta), size=n_devices)

    per_dev_idx = []
    for d in range(n_devices):
        # largest-remainder apportionment of `per` slots to classes per q_d
        raw = props[d] * per
        counts = np.floor(raw).astype(int)
        short = per - counts.sum()
        counts[np.argsort(raw - counts)[::-1][:short]] += 1

        idx = []
        for c, want in zip(classes, counts):
            take = min(want, len(pools[c]))
            idx.extend(pools[c][:take])
            pools[c] = pools[c][take:]
        # top up from whatever classes still have samples
        while len(idx) < per:
            c = max(pools, key=lambda c: len(pools[c]))
            idx.append(pools[c].pop(0))
        idx = np.asarray(idx[:per])
        rng.shuffle(idx)
        per_dev_idx.append(idx)

    per_dev_idx = np.stack(per_dev_idx)
    return DeviceData(features=features[per_dev_idx], labels=labels[per_dev_idx])
