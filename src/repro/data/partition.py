"""Non-IID federated partitioning (paper Sec. V-A + sim scenario presets).

Sort-by-label sharding: sort the M training samples by label, split into
``n_devices * shards_per_device`` shards, assign each device
``shards_per_device`` random shards — each device then holds (about)
``shards_per_device`` classes. ``classes_per_device`` (paper's C) equals
``shards_per_device`` for balanced class counts.

``partition_dirichlet`` adds the Dirichlet(β) label-skew partition standard
in the FL literature (Hsu et al. 2019), equalized to stacked per-device
shards so it plugs into the same ``DeviceData`` interface.

``partition_dirichlet_sized`` instead skews the *shard sizes*: m_i ~
Dir(β)·M (unequal data volumes, the regime the Eq. 34/35/37 m_i/M weights
are written for). Shards are padded to a common length and the true counts
ride in ``DeviceData.n_samples`` — padded rows are never sampled by the
round pipeline.

``partition_dirichlet_mixed`` composes both skews in one preset: unequal
m_i ~ Dir(β_size)·M shard sizes AND per-device Dirichlet(β) label
proportions — the fully-heterogeneous regime (devices differ in both how
much data they hold and which classes it covers).
"""
from __future__ import annotations

import numpy as np

from repro.core.pofl import DeviceData


def partition_noniid_shards(
    features,
    labels,
    n_devices: int,
    shards_per_device: int = 2,
    seed: int = 0,
) -> DeviceData:
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    n_shards = n_devices * shards_per_device
    shard_size = m_total // n_shards

    order = np.argsort(labels, kind="stable")
    rng = np.random.default_rng(seed)
    shard_ids = rng.permutation(n_shards)

    per_dev_feats, per_dev_labels = [], []
    for d in range(n_devices):
        idx = []
        for s in shard_ids[d * shards_per_device : (d + 1) * shards_per_device]:
            idx.append(order[s * shard_size : (s + 1) * shard_size])
        idx = np.concatenate(idx)
        rng.shuffle(idx)
        per_dev_feats.append(features[idx])
        per_dev_labels.append(labels[idx])

    return DeviceData(
        features=np.stack(per_dev_feats),
        labels=np.stack(per_dev_labels),
    )


def partition_iid(features, labels, n_devices: int, seed: int = 0) -> DeviceData:
    """IID control: uniformly random equal split."""
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    per = m_total // n_devices
    rng = np.random.default_rng(seed)
    perm = rng.permutation(m_total)[: per * n_devices].reshape(n_devices, per)
    return DeviceData(features=features[perm], labels=labels[perm])


def _apportion_by_label(labels, sizes, beta: float, rng) -> list[np.ndarray]:
    """Dirichlet(β) label apportionment shared by ``partition_dirichlet``
    (equal sizes) and ``partition_dirichlet_mixed`` (Dirichlet sizes).

    Device d gets ``sizes[d]`` samples whose labels follow q_d ~ Dir(β·1_K):
    largest-remainder apportionment of its slots to classes, drawn from
    per-class pools, topping up from the fullest remaining pool when a class
    runs dry — every sample is used at most once (exactly once when
    Σ sizes = M).
    """
    classes = np.unique(labels)
    pools = {c: rng.permutation(np.flatnonzero(labels == c)).tolist() for c in classes}
    props = rng.dirichlet(np.full(len(classes), beta), size=len(sizes))

    per_dev_idx = []
    for d, per in enumerate(sizes):
        per = int(per)
        raw = props[d] * per
        counts = np.floor(raw).astype(int)
        short = per - counts.sum()
        counts[np.argsort(raw - counts)[::-1][:short]] += 1

        idx = []
        for c, want in zip(classes, counts):
            take = min(want, len(pools[c]))
            idx.extend(pools[c][:take])
            pools[c] = pools[c][take:]
        # top up from whatever classes still have samples
        while len(idx) < per:
            c = max(pools, key=lambda c: len(pools[c]))
            idx.append(pools[c].pop(0))
        idx = np.asarray(idx[:per])
        rng.shuffle(idx)
        per_dev_idx.append(idx)
    return per_dev_idx


def partition_dirichlet(
    features,
    labels,
    n_devices: int,
    beta: float = 0.5,
    seed: int = 0,
) -> DeviceData:
    """Dirichlet(β) label-proportion partition, equalized to stacked shards.

    Device d's label distribution is q_d ~ Dir(β·1_K); its m = M//N samples
    are drawn class-by-class to match q_d from per-class pools, topping up
    from the leftover pool when a class runs dry (so shards stay equal-size
    and every sample is used at most once). β→0 gives near-single-class
    devices; β→∞ recovers the global label distribution.
    """
    features = np.asarray(features)
    labels = np.asarray(labels)
    per = labels.shape[0] // n_devices
    rng = np.random.default_rng(seed)
    per_dev_idx = np.stack(
        _apportion_by_label(labels, [per] * n_devices, beta, rng)
    )
    return DeviceData(features=features[per_dev_idx], labels=labels[per_dev_idx])


def dirichlet_sizes(
    m_total: int,
    n_devices: int,
    beta: float = 0.5,
    min_per_device: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """Draw unequal shard sizes m_i ~ Dir(β·1_N)·M with Σm_i = M.

    Largest-remainder apportionment of the M slots to the Dirichlet
    proportions, then a repair pass lifting devices below ``min_per_device``
    by taking from the largest shards. β→0 concentrates the data on few
    devices; β→∞ recovers equal shards.
    """
    if n_devices * min_per_device > m_total:
        raise ValueError(
            f"cannot give {n_devices} devices ≥{min_per_device} of {m_total} samples"
        )
    rng = np.random.default_rng(seed)
    props = rng.dirichlet(np.full(n_devices, beta))
    raw = props * m_total
    sizes = np.floor(raw).astype(int)
    short = m_total - sizes.sum()
    sizes[np.argsort(raw - sizes)[::-1][:short]] += 1
    while (sizes < min_per_device).any():
        sizes[np.argmax(sizes)] -= 1
        sizes[np.argmin(sizes)] += 1
    return sizes


def partition_dirichlet_mixed(
    features,
    labels,
    n_devices: int,
    beta: float = 0.5,
    beta_size: float = 0.5,
    min_per_device: int = 1,
    seed: int = 0,
) -> DeviceData:
    """Label-skew × size-skew: Dir(β) class proportions over Dir(β_size)·M
    unequal shard sizes (the ROADMAP ``dirichlet`` × ``dirichlet_sized``
    composition).

    Device d holds m_d ~ :func:`dirichlet_sizes`(β_size) samples whose labels
    follow q_d ~ Dir(β·1_K) (largest-remainder apportionment of m_d slots to
    classes, topping up from the fullest per-class pool when one runs dry, so
    every sample is used exactly once). Shards are wrap-padded to m_max and
    the true counts ride in ``DeviceData.n_samples`` exactly like
    :func:`partition_dirichlet_sized`.
    """
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    sizes = dirichlet_sizes(
        m_total, n_devices, beta=beta_size, min_per_device=min_per_device,
        seed=seed,
    )
    rng = np.random.default_rng(seed + 1)
    per_dev_idx = _apportion_by_label(labels, sizes, beta, rng)

    m_max = int(sizes.max())
    idx_pad = np.stack([np.resize(idx, m_max) for idx in per_dev_idx])  # wrap-pad
    return DeviceData(
        features=features[idx_pad],
        labels=labels[idx_pad],
        n_samples=sizes.astype(np.int32),
    )


def partition_dirichlet_sized(
    features,
    labels,
    n_devices: int,
    beta: float = 0.5,
    min_per_device: int = 1,
    seed: int = 0,
) -> DeviceData:
    """Dirichlet(β) *shard-size* partition: unequal m_i, random content.

    Sizes come from :func:`dirichlet_sizes`; samples are assigned by a global
    random permutation (IID content — compose with label skew by shuffling
    labels upstream if both are wanted). Shards are padded to m_max by
    wrapping each device's own valid samples, and the true counts are
    recorded in ``DeviceData.n_samples`` — the round pipeline only ever
    samples indices below n_samples[i], so padding content is inert.
    """
    features = np.asarray(features)
    labels = np.asarray(labels)
    m_total = labels.shape[0]
    sizes = dirichlet_sizes(
        m_total, n_devices, beta=beta, min_per_device=min_per_device, seed=seed
    )
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(m_total)

    m_max = int(sizes.max())
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    idx_pad = np.stack([
        np.resize(perm[bounds[d] : bounds[d + 1]], m_max)  # wrap-pad
        for d in range(n_devices)
    ])
    return DeviceData(
        features=features[idx_pad],
        labels=labels[idx_pad],
        n_samples=sizes.astype(np.int32),
    )
