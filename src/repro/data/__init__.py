from repro.data.partition import (
    partition_dirichlet,
    partition_iid,
    partition_noniid_shards,
)
from repro.data.synthetic import make_classification_dataset, make_token_dataset

__all__ = [
    "make_classification_dataset",
    "make_token_dataset",
    "partition_dirichlet",
    "partition_iid",
    "partition_noniid_shards",
]
