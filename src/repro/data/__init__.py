from repro.data.partition import (
    dirichlet_sizes,
    partition_dirichlet,
    partition_dirichlet_mixed,
    partition_dirichlet_sized,
    partition_iid,
    partition_noniid_shards,
)
from repro.data.synthetic import make_classification_dataset, make_token_dataset

__all__ = [
    "dirichlet_sizes",
    "make_classification_dataset",
    "make_token_dataset",
    "partition_dirichlet",
    "partition_dirichlet_mixed",
    "partition_dirichlet_sized",
    "partition_iid",
    "partition_noniid_shards",
]
