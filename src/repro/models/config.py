"""Model configuration dataclasses for every supported architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0      # llama4-style always-on shared expert
    capacity_factor: float = 1.25  # GShard-style dispatch capacity


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + one *shared* attention block invoked
    every ``attn_every`` layers (weights shared across invocations)."""

    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    n_enc_frames: int = 1024  # precomputed speech-frame embeddings (stub input)


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256  # precomputed ViT patch embeddings (stub input)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # ring-buffer window for long-context
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    source: str = ""  # citation for the config numbers

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so logits shard over 16-way axes."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode with a 500k context is sub-quadratic / bounded-state:
        native for SSM/hybrid, via sliding window otherwise."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D roofline)."""
        d, v = self.d_model, self.vocab_padded
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        dh = self.head_dim
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + self.n_heads * dh * d
        dense_mlp = 3 * d * self.d_ff  # SwiGLU
        if self.arch_type in ("dense", "vlm"):
            per_layer = attn + dense_mlp
        elif self.arch_type == "moe":
            moe = self.moe
            expert = 3 * d * moe.d_ff_expert
            per_layer = attn + moe.n_experts * expert + d * moe.n_experts
            per_layer += moe.n_shared_experts * expert
        elif self.arch_type == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per_layer = d * (2 * di + 2 * s.d_state + nh) + di * s.conv_kernel + di * d
        elif self.arch_type == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per_layer = d * (2 * di + 2 * s.d_state + nh) + di * s.conv_kernel + di * d
        elif self.arch_type == "encdec":
            # decoder layer: self-attn + cross-attn + mlp
            per_layer = 2 * attn + dense_mlp
        n += self.n_layers * per_layer
        if self.arch_type == "hybrid":
            n += attn + dense_mlp  # one shared block
        if self.arch_type == "encdec":
            n += self.encdec.n_enc_layers * (attn + dense_mlp)
        n += 2 * d * self.n_layers  # norms (approx)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count except MoE, where only
        top_k of n_experts experts fire) — the N in MODEL_FLOPS = 6·N·D."""
        if self.arch_type != "moe":
            return self.param_count()
        moe = self.moe
        expert = 3 * self.d_model * moe.d_ff_expert
        inactive = (moe.n_experts - moe.top_k) * expert
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
