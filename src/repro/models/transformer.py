"""Decoder-only model stacks (dense / moe / ssm / hybrid / vlm).

One homogeneous layer body scanned over the stacked layer parameters
(fast compiles, remat-friendly); the Zamba2-style hybrid applies a *shared*
attention block every ``hybrid.attn_every`` layers via lax.cond.

Public entry points:
  init_model(cfg, key)                       -> params
  forward(params, cfg, tokens, ...)          -> logits        (train / prefill)
  lm_loss(params, cfg, tokens, ...)          -> (loss, aux)
  prefill(params, cfg, tokens, ...)          -> (logits, cache)
  decode_step(params, cfg, token, cache, t)  -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.cache import (
    AttnCache,
    HybridCache,
    SSMCache,
    init_cache,
    n_shared_invocations,
)
from repro.models.config import ModelConfig

# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    if cfg.arch_type in ("dense", "vlm"):
        return {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
        }
    if cfg.arch_type == "moe":
        return {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "moe": L.init_moe(ks[1], cfg),
        }
    if cfg.arch_type in ("ssm", "hybrid"):
        return {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "mamba": L.init_mamba2(ks[0], cfg),
        }
    raise ValueError(cfg.arch_type)


def init_model(cfg: ModelConfig, key) -> dict:
    k_embed, k_layers, k_head, k_extra = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": L.dense_init(k_embed, (cfg.vocab_padded, cfg.d_model), scale=0.02),
        "layers": jax.vmap(partial(_init_layer, cfg))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_padded))
    if cfg.arch_type == "hybrid":
        ks = jax.random.split(k_extra, 2)
        params["shared_block"] = {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
        }
    if cfg.arch_type == "vlm":
        params["vis_proj"] = L.dense_init(k_extra, (cfg.d_model, cfg.d_model))
    return params


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------


def _shared_block_fwd(sp, x, cfg, dtype, return_kv=False):
    h = L.attention_fwd(
        sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps), cfg,
        dtype=dtype, return_kv=return_kv,
    )
    if return_kv:
        h, kv = h
    x = x + h
    x = x + L.mlp_fwd(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps), dtype)
    if return_kv:
        return x, kv
    return x


def _layer_fwd(cfg: ModelConfig, shared, lp, x, idx, dtype):
    """One scanned layer. Returns (x, aux)."""
    x = L.constrain(x, "residual")
    lp = L.constrain_tree(lp, "layer_params")
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type in ("dense", "vlm"):
        x = x + L.attention_fwd(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, dtype=dtype
        )
        x = x + L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
    elif cfg.arch_type == "moe":
        x = x + L.attention_fwd(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, dtype=dtype
        )
        h, aux = L.moe_fwd(lp["moe"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg, dtype)
        x = x + h
    elif cfg.arch_type == "ssm":
        x = x + L.mamba2_fwd(lp["mamba"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, dtype)
    elif cfg.arch_type == "hybrid":
        x = jax.lax.cond(
            idx % cfg.hybrid.attn_every == 0,
            lambda v: _shared_block_fwd(shared, v, cfg, dtype),
            lambda v: v,
            x,
        )
        x = x + L.mamba2_fwd(lp["mamba"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, dtype)
    return x, aux


def embed_inputs(params, cfg: ModelConfig, tokens, embeds, dtype):
    """Token embedding; VLM prepends projected patch embeddings."""
    x = params["embed"].astype(dtype)[tokens]
    if cfg.arch_type == "vlm":
        assert embeds is not None, "vlm requires patch embeddings"
        vis = embeds.astype(dtype) @ params["vis_proj"].astype(dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def backbone(params, cfg: ModelConfig, x, dtype, remat: bool = False):
    """Scan the layer stack. x: (B, S, D) -> (B, S, D), aux."""
    shared = params.get("shared_block")

    def body(carry, inp):
        x, aux = carry
        lp, idx = inp
        x, a = _layer_fwd(cfg, shared, lp, x, idx, dtype)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)

    (x, aux), _ = jax.lax.scan(
        body,
        (x, jnp.zeros((), jnp.float32)),
        (params["layers"], jnp.arange(cfg.n_layers)),
    )
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def logits_from_hidden(params, cfg: ModelConfig, x, dtype):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dtype)
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def forward(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    embeds: Optional[jnp.ndarray] = None,
    dtype=jnp.float32,
    remat: bool = False,
):
    """Full-sequence logits. For VLM the logits cover only token positions."""
    x = embed_inputs(params, cfg, tokens, embeds, dtype)
    x, aux = backbone(params, cfg, x, dtype, remat)
    if cfg.arch_type == "vlm":
        x = x[:, embeds.shape[1]:, :]
    return logits_from_hidden(params, cfg, x, dtype), aux


CE_CHUNK = 1024  # sequence-chunked cross entropy: (B, CHUNK, V) logits live,
                 # never the full (B, S, V) — at production vocab (150k–256k)
                 # the full logits tensor would be hundreds of GB.


def chunked_ce(params, cfg: ModelConfig, x, tokens, dtype, logits_sharding=None):
    """Per-example mean NLL of next-token prediction, computed in sequence
    chunks with rematerialization. x: (B, S, D) final hidden; tokens (B, S).

    ``logits_sharding``: optional NamedSharding for each (B, CHUNK, V) logits
    chunk — shard V over "model" or the chunk is replicated across the model
    axis (a ~10 GB/device regression at 150k vocab; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    # re-shard the hidden stream D×"model" before the sequence slicing
    # below: with S×"model" (sequence-parallel residual) the uneven [:-1]
    # slice forces XLA to re-lay-out, and it picks batch-replicated copies
    # (~1 GB each at 76B scale; §Perf iteration 4)
    x = L.constrain(x, "ce_input")
    s1 = s - 1
    chunk = min(CE_CHUNK, s1)
    nc = -(-s1 // chunk)
    pad = nc * chunk - s1
    x_in = jnp.pad(x[:, :-1], ((0, 0), (0, pad), (0, 0)))
    tgt = jnp.pad(tokens[:, 1:], ((0, 0), (0, pad)))
    valid = jnp.pad(jnp.ones((b, s1), jnp.float32), ((0, 0), (0, pad)))

    xs = jnp.moveaxis(x_in.reshape(b, nc, chunk, d), 1, 0)
    tgts = jnp.moveaxis(tgt.reshape(b, nc, chunk), 1, 0)
    valids = jnp.moveaxis(valid.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        xc, tc, vc = inp
        logits = logits_from_hidden(params, cfg, xc, dtype)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(nll * vc, axis=-1), None

    acc, _ = jax.lax.scan(body, jnp.zeros((b,), jnp.float32), (xs, tgts, valids))
    return acc / s1


def lm_loss(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    embeds: Optional[jnp.ndarray] = None,
    dtype=jnp.float32,
    remat: bool = False,
    loss_weights: Optional[jnp.ndarray] = None,
    aux_coeff: float = 0.01,
    reduce: bool = True,
    logits_sharding=None,
):
    """Next-token cross entropy (+ MoE aux). ``loss_weights``: per-example
    weights (B,) — the hook the PO-FL trainer uses for device reweighting.
    ``reduce=False`` returns the per-example loss vector (B,) instead of the
    scalar (used by the per-FL-device statistics passes)."""
    x = embed_inputs(params, cfg, tokens, embeds, dtype)
    x, aux = backbone(params, cfg, x, dtype, remat)
    if cfg.arch_type == "vlm":
        x = x[:, embeds.shape[1]:, :]
    per_example = chunked_ce(params, cfg, x, tokens, dtype, logits_sharding)  # (B,)
    if loss_weights is not None:
        per_example = per_example * loss_weights
    if not reduce:
        return per_example, aux
    return jnp.mean(per_example) + aux_coeff * aux, aux


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    embeds: Optional[jnp.ndarray] = None,
    dtype=jnp.float32,
):
    """Run the full prompt, build the decode cache, return last-pos logits."""
    b, s = tokens.shape
    x = embed_inputs(params, cfg, tokens, embeds, dtype)
    s_total = x.shape[1]
    shared = params.get("shared_block")

    if cfg.arch_type in ("dense", "vlm", "moe"):

        def body(x, lp):
            x = L.constrain(x, "residual")
            lp = L.constrain_tree(lp, "layer_params")
            h, kv = L.attention_fwd(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
                dtype=dtype, return_kv=True,
            )
            x = x + h
            if cfg.arch_type == "moe":
                m, _ = L.moe_fwd(lp["moe"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg, dtype)
            else:
                m = L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
            return x + m, kv

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache = AttnCache(k=ks, v=vs, pos=jnp.arange(s_total, dtype=jnp.int32))
    elif cfg.arch_type == "ssm":
        # SSD prefill: run full sequence, then reconstruct the final state by
        # a single recurrent pass over the last conv-kernel window is NOT
        # sufficient — instead we run chunked SSD and also emit final states.
        x, cache = _ssm_prefill(params, cfg, x, dtype)
    elif cfg.arch_type == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, dtype)
    else:
        raise ValueError(cfg.arch_type)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :], dtype)
    return logits, cache


def _mamba_layer_with_state(lp, x, cfg, dtype):
    """Full-sequence Mamba2 that also returns (ssm_state, conv_state)."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di, nh, n = s_cfg.d_inner(d), s_cfg.n_heads(d), s_cfg.d_state

    h_in = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    zxbcdt = h_in @ lp["mamba"]["in_proj"].astype(dtype)
    z, xbc, dt = L._split_mamba_proj(zxbcdt, di, n, nh)
    conv_state = xbc[:, -(s_cfg.conv_kernel - 1):, :]
    xbc = jax.nn.silu(
        L.causal_conv1d(xbc, lp["mamba"]["conv_w"].astype(dtype), lp["mamba"]["conv_b"].astype(dtype))
    )
    xin, B, C = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["mamba"]["dt_bias"])
    la = -jnp.exp(lp["mamba"]["A_log"])[None, None, :] * dt
    xh = xin.reshape(*xin.shape[:-1], nh, s_cfg.head_dim)
    xdt = xh * dt[..., None].astype(dtype)

    chunk = min(s_cfg.chunk_size, x.shape[1])
    y = L.ssd_chunked(xdt, la.astype(jnp.float32), B, C, chunk)

    # final state: replay the decay-weighted sum over the whole sequence
    La = jnp.cumsum(la, axis=1)  # (B,S,H)
    seg = jnp.exp(La[:, -1:, :] - La)  # decay from t to sequence end
    final_state = jnp.einsum("bsh,bsn,bshp->bhnp", seg.astype(dtype), B, xdt)

    y = y + lp["mamba"]["D"].astype(dtype)[None, None, :, None] * xh
    y = y.reshape(*y.shape[:-2], di)
    y = L.rmsnorm(lp["mamba"]["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = x + y @ lp["mamba"]["out_proj"].astype(dtype)
    return out, final_state, conv_state


def _ssm_prefill(params, cfg, x, dtype):
    def body(x, lp):
        x = L.constrain(x, "residual")
        lp = L.constrain_tree(lp, "layer_params")
        out, st, cv = _mamba_layer_with_state(lp, x, cfg, dtype)
        return out, (st, cv)

    x, (states, convs) = jax.lax.scan(body, x, params["layers"])
    return x, SSMCache(state=states, conv=convs)


def _hybrid_prefill(params, cfg, x, dtype):
    shared = params["shared_block"]
    every = cfg.hybrid.attn_every
    n_inv = n_shared_invocations(cfg)
    s_total = x.shape[1]
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    ks0 = jnp.zeros((n_inv, x.shape[0], s_total, kv, dh), dtype)
    vs0 = jnp.zeros_like(ks0)

    def body(carry, inp):
        x, ks, vs = carry
        x = L.constrain(x, "residual")
        lp, idx = inp
        lp = L.constrain_tree(lp, "layer_params")

        def with_attn(x):
            out, (k, v) = _shared_block_fwd(shared, x, cfg, dtype, return_kv=True)
            return out, k, v

        def without(x):
            z = jnp.zeros((x.shape[0], s_total, kv, dh), dtype)
            return x, z, z

        x2, k, v = jax.lax.cond(idx % every == 0, with_attn, without, x)
        inv = idx // every
        write = (idx % every == 0).astype(dtype)
        ks = jax.lax.dynamic_update_index_in_dim(
            ks, write * k + (1 - write) * jax.lax.dynamic_index_in_dim(ks, inv, 0, False),
            inv, 0)
        vs = jax.lax.dynamic_update_index_in_dim(
            vs, write * v + (1 - write) * jax.lax.dynamic_index_in_dim(vs, inv, 0, False),
            inv, 0)
        out, st, cv = _mamba_layer_with_state(lp, x2, cfg, dtype)
        return (out, ks, vs), (st, cv)

    (x, ks, vs), (states, convs) = jax.lax.scan(
        body, (x, ks0, vs0), (params["layers"], jnp.arange(cfg.n_layers))
    )
    cache = HybridCache(
        ssm=SSMCache(state=states, conv=convs),
        attn=AttnCache(k=ks, v=vs, pos=jnp.arange(s_total, dtype=jnp.int32)),
    )
    return x, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def decode_step(
    params,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) int32
    cache,
    t: jnp.ndarray,      # scalar int32 — absolute position of this token
    dtype=jnp.float32,
):
    """One serve step: consume one token, update the cache, emit logits."""
    x = params["embed"].astype(dtype)[token]

    if cfg.arch_type in ("dense", "vlm", "moe"):
        s_max = cache.k.shape[2]
        slot = (t % s_max).astype(jnp.int32)
        new_pos = jax.lax.dynamic_update_slice(
            cache.pos, t[None].astype(jnp.int32), (slot,)
        )

        def body(x, lp_kv):
            lp, ck, cv = lp_kv
            h, (ck, cv, _) = L.attention_decode(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
                ck, cv, new_pos, t, dtype=dtype,
            )
            x = x + h
            if cfg.arch_type == "moe":
                m, _ = L.moe_fwd(lp["moe"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg, dtype)
            else:
                m = L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
            return x + m, (ck, cv)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
        new_cache = AttnCache(k=ks, v=vs, pos=new_pos)
    elif cfg.arch_type == "ssm":

        def body(x, lp_state):
            lp, st, cv = lp_state
            h_in = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, st, cv = L.mamba2_decode(lp["mamba"], h_in, cfg, st, cv, dtype)
            return x + h, (st, cv)

        x, (states, convs) = jax.lax.scan(
            body, x, (params["layers"], cache.state, cache.conv)
        )
        new_cache = SSMCache(state=states, conv=convs)
    elif cfg.arch_type == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, x, cache, t, dtype)
    else:
        raise ValueError(cfg.arch_type)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x, dtype), new_cache


def _hybrid_decode(params, cfg, x, cache: HybridCache, t, dtype):
    shared = params["shared_block"]
    every = cfg.hybrid.attn_every
    s_max = cache.attn.k.shape[2]
    slot = (t % s_max).astype(jnp.int32)
    new_pos = jax.lax.dynamic_update_slice(
        cache.attn.pos, t[None].astype(jnp.int32), (slot,)
    )

    def body(carry, inp):
        x, ks, vs = carry
        lp, st, cv, idx = inp

        def with_attn(args):
            x, ks, vs = args
            inv = idx // every
            ck = jax.lax.dynamic_index_in_dim(ks, inv, 0, keepdims=False)
            cvv = jax.lax.dynamic_index_in_dim(vs, inv, 0, keepdims=False)
            h, (ck, cvv, _) = L.attention_decode(
                shared["attn"], L.rmsnorm(shared["ln1"], x, cfg.norm_eps), cfg,
                ck, cvv, new_pos, t, dtype=dtype,
            )
            x = x + h
            x = x + L.mlp_fwd(shared["mlp"], L.rmsnorm(shared["ln2"], x, cfg.norm_eps), dtype)
            ks = jax.lax.dynamic_update_index_in_dim(ks, ck, inv, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, cvv, inv, 0)
            return x, ks, vs

        x, ks, vs = jax.lax.cond(
            idx % every == 0, with_attn, lambda a: a, (x, ks, vs)
        )
        h_in = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        h, st, cv = L.mamba2_decode(lp["mamba"], h_in, cfg, st, cv, dtype)
        return (x + h, ks, vs), (st, cv)

    (x, ks, vs), (states, convs) = jax.lax.scan(
        body,
        (x, cache.attn.k, cache.attn.v),
        (params["layers"], cache.ssm.state, cache.ssm.conv, jnp.arange(cfg.n_layers)),
    )
    return x, HybridCache(
        ssm=SSMCache(state=states, conv=convs),
        attn=AttnCache(k=ks, v=vs, pos=new_pos),
    )
