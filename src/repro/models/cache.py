"""Decode caches: KV (full or ring-buffer) and SSM recurrent state."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class AttnCache(NamedTuple):
    k: jnp.ndarray    # (L, B, S_cache, KV, dh)
    v: jnp.ndarray    # (L, B, S_cache, KV, dh)
    pos: jnp.ndarray  # (S_cache,) absolute position per slot, -1 = empty


class SSMCache(NamedTuple):
    state: jnp.ndarray  # (L, B, H, N, P)
    conv: jnp.ndarray   # (L, B, K-1, di+2n)


class HybridCache(NamedTuple):
    ssm: SSMCache
    attn: AttnCache  # leading dim = number of shared-block invocations


class EncDecCache(NamedTuple):
    self_attn: AttnCache   # decoder self-attention cache
    cross_k: jnp.ndarray   # (L, B, S_enc, KV, dh) — encoder keys (fixed)
    cross_v: jnp.ndarray


def cache_seq_len(cfg: ModelConfig, context_len: int) -> int:
    """Ring-buffer caches only keep the window."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, context_len)
    return context_len


def init_attn_cache(
    cfg: ModelConfig, batch: int, context_len: int, n_layers: Optional[int] = None,
    dtype=jnp.float32,
) -> AttnCache:
    L = n_layers if n_layers is not None else cfg.n_layers
    s = cache_seq_len(cfg, context_len)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return AttnCache(
        k=jnp.zeros((L, batch, s, kv, dh), dtype),
        v=jnp.zeros((L, batch, s, kv, dh), dtype),
        pos=jnp.full((s,), -1, jnp.int32),
    )


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    s = cfg.ssm
    d = cfg.d_model
    nh = s.n_heads(d)
    return SSMCache(
        state=jnp.zeros((cfg.n_layers, batch, nh, s.d_state, s.head_dim), dtype),
        conv=jnp.zeros(
            (cfg.n_layers, batch, s.conv_kernel - 1, s.d_inner(d) + 2 * s.d_state),
            dtype,
        ),
    )


def n_shared_invocations(cfg: ModelConfig) -> int:
    return (cfg.n_layers + cfg.hybrid.attn_every - 1) // cfg.hybrid.attn_every


def pad_cache(cache, total_len: int):
    """Grow a prefill-sized cache to decode capacity ``total_len`` (attention
    caches pad the sequence dim with empty slots, pos = -1; SSM state is O(1)
    and unchanged)."""

    def pad_attn(c: AttnCache) -> AttnCache:
        s = c.k.shape[2]
        extra = total_len - s
        if extra <= 0:
            return c
        pad_kv = [(0, 0)] * c.k.ndim
        pad_kv[2] = (0, extra)
        return AttnCache(
            k=jnp.pad(c.k, pad_kv),
            v=jnp.pad(c.v, pad_kv),
            pos=jnp.pad(c.pos, (0, extra), constant_values=-1),
        )

    if isinstance(cache, HybridCache):
        return HybridCache(ssm=cache.ssm, attn=pad_attn(cache.attn))
    if isinstance(cache, EncDecCache):
        return EncDecCache(
            self_attn=pad_attn(cache.self_attn),
            cross_k=cache.cross_k,
            cross_v=cache.cross_v,
        )
    if isinstance(cache, SSMCache):
        return cache
    return pad_attn(cache)


def init_cache(cfg: ModelConfig, batch: int, context_len: int, dtype=jnp.float32):
    if cfg.arch_type == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if cfg.arch_type == "hybrid":
        return HybridCache(
            ssm=init_ssm_cache(cfg, batch, dtype),
            attn=init_attn_cache(
                cfg, batch, context_len, n_layers=n_shared_invocations(cfg), dtype=dtype
            ),
        )
    if cfg.arch_type == "encdec":
        enc_len = cfg.encdec.n_enc_frames
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        return EncDecCache(
            self_attn=init_attn_cache(cfg, batch, context_len, dtype=dtype),
            cross_k=jnp.zeros((cfg.n_layers, batch, enc_len, kv, dh), dtype),
            cross_v=jnp.zeros((cfg.n_layers, batch, enc_len, kv, dh), dtype),
        )
    return init_attn_cache(cfg, batch, context_len, dtype=dtype)
