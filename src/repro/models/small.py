"""Paper-scale evaluation models (Sec. V-A).

  * logistic regression on 784-dim inputs (MNIST case — convex)
  * 4-conv-layer CNN on 32×32×3 inputs (CIFAR-10 case — non-convex),
    adapted from the paper's reference CNN (conv 3→32→64→128→128, 2×2 pools,
    one hidden dense layer).

Pure-function (init, loss, accuracy) triples over dict pytrees, matching the
core/pofl.py simulator interface.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# logistic regression (convex)
# --------------------------------------------------------------------------


def init_logreg(key, dim: int = 784, n_classes: int = 10):
    kw, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(kw, (dim, n_classes)) * 0.01,
        "b": jnp.zeros((n_classes,)),
    }


def logreg_logits(params, x):
    x = x.reshape(x.shape[0], -1)
    return x @ params["w"] + params["b"]


def logreg_loss(params, x, y):
    logits = logreg_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


# --------------------------------------------------------------------------
# 4-conv CNN (non-convex)
# --------------------------------------------------------------------------

_CNN_CHANNELS = (32, 64, 128, 128)


def init_cnn(key, n_classes: int = 10, in_ch: int = 3):
    ks = jax.random.split(key, 6)
    params = {}
    c_prev = in_ch
    for i, c in enumerate(_CNN_CHANNELS):
        params[f"conv{i}"] = {
            "w": jax.random.normal(ks[i], (3, 3, c_prev, c))
            * jnp.sqrt(2.0 / (9 * c_prev)),
            "b": jnp.zeros((c,)),
        }
        c_prev = c
    # two 2×2 pools over 32×32 → 8×8 spatial, then global-average → c_prev
    params["fc1"] = {
        "w": jax.random.normal(ks[4], (c_prev, 128)) * jnp.sqrt(2.0 / c_prev),
        "b": jnp.zeros((128,)),
    }
    params["out"] = {
        "w": jax.random.normal(ks[5], (128, n_classes)) * jnp.sqrt(1.0 / 128),
        "b": jnp.zeros((n_classes,)),
    }
    return params


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(y + p["b"])


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_logits(params, x):
    x = _conv(x, params["conv0"])
    x = _conv(x, params["conv1"])
    x = _pool(x)
    x = _conv(x, params["conv2"])
    x = _pool(x)
    x = _conv(x, params["conv3"])
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["out"]["w"] + params["out"]["b"]


def cnn_loss(params, x, y):
    logp = jax.nn.log_softmax(cnn_logits(params, x), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


# --------------------------------------------------------------------------
# shared eval
# --------------------------------------------------------------------------


def make_eval_fn(
    logits_fn, loss_fn, x_test, y_test, batch: int = 1000,
    n_valid: int | None = None,
):
    """Jitted ``params -> (loss, acc)`` over (up to) ``batch`` test rows.

    ``n_valid`` marks the TRUE-sample prefix of a padded test set (the same
    valid-prefix contract as ``core.pofl.DeviceData.n_samples``): rows at
    and past ``n_valid`` are padding and must not count toward loss or
    accuracy, so the eval window is ``min(batch, n_valid)`` rows. ``None``
    (the historical default) treats every row as valid — bit-identical to
    the pre-``n_valid`` eval.
    """
    n_rows = int(jnp.shape(y_test)[0])
    n = min(batch, n_rows) if n_valid is None else min(batch, int(n_valid))
    if not 0 < n <= n_rows:
        raise ValueError(f"n_valid must be in [1, {n_rows}] (got {n_valid})")

    @jax.jit
    def _eval(params):
        logits = logits_fn(params, x_test[:n])
        acc = jnp.mean(jnp.argmax(logits, -1) == y_test[:n])
        loss = loss_fn(params, x_test[:n], y_test[:n])
        return loss, acc

    return _eval
