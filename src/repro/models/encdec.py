"""Encoder-decoder stack (SeamlessM4T-style speech-to-text backbone).

The modality frontend (mel-spectrogram + conformer feature extractor) is a
stub per the assignment: the encoder consumes precomputed frame embeddings
``(B, n_frames, d_model)`` provided by input_specs().
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.cache import AttnCache, EncDecCache
from repro.models.config import ModelConfig
from repro.models.transformer import logits_from_hidden


def _init_enc_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "self_attn": L.init_attention(ks[0], cfg),
        "ln_x": L.init_rmsnorm(cfg.d_model),
        "cross_attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_encdec(cfg: ModelConfig, key) -> dict:
    k_embed, k_enc, k_dec, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.encdec.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": L.dense_init(k_embed, (cfg.vocab_padded, cfg.d_model), scale=0.02),
        "enc_layers": jax.vmap(partial(_init_enc_layer, cfg))(enc_keys),
        "layers": jax.vmap(partial(_init_dec_layer, cfg))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab_padded)),
    }


def encode(params, cfg: ModelConfig, frames: jnp.ndarray, dtype=jnp.float32):
    """Bidirectional encoder over precomputed frame embeddings."""

    def body(x, lp):
        x = L.constrain(x, "residual")
        lp = L.constrain_tree(lp, "enc_layer_params")
        x = x + L.attention_fwd(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            causal=False, dtype=dtype,
        )
        x = x + L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
        return x, None

    x, _ = jax.lax.scan(body, frames.astype(dtype), params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg: ModelConfig, dtype):
    b, s, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ lp["cross_attn"]["wk"].astype(dtype))
    v = (enc_out @ lp["cross_attn"]["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + lp["cross_attn"]["bk"].astype(dtype)
        v = v + lp["cross_attn"]["bv"].astype(dtype)
    return k.reshape(b, s, kv, dh), v.reshape(b, s, kv, dh)


def _decoder_layer(lp, x, enc_out, cfg, dtype, return_kv=False):
    x = L.constrain(x, "residual")
    lp = L.constrain_tree(lp, "layer_params")
    h = L.attention_fwd(
        lp["self_attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
        dtype=dtype, return_kv=return_kv,
    )
    if return_kv:
        h, kv = h
    x = x + h
    ckv = _cross_kv(lp, enc_out, cfg, dtype)
    x = x + L.attention_fwd(
        lp["cross_attn"], L.rmsnorm(lp["ln_x"], x, cfg.norm_eps), cfg,
        kv_override=ckv, dtype=dtype, use_rope=False,
    )
    x = x + L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
    if return_kv:
        return x, (kv, ckv)
    return x


def _decoder_hidden(params, cfg, tokens, frames, dtype, remat):
    enc_out = encode(params, cfg, frames, dtype)
    x = params["embed"].astype(dtype)[tokens]

    def body(x, lp):
        return _decoder_layer(lp, x, enc_out, cfg, dtype), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward_encdec(
    params, cfg: ModelConfig, tokens: jnp.ndarray, frames: jnp.ndarray,
    dtype=jnp.float32, remat: bool = False,
):
    x = _decoder_hidden(params, cfg, tokens, frames, dtype, remat)
    return logits_from_hidden(params, cfg, x, dtype)


def encdec_loss(
    params, cfg: ModelConfig, tokens, frames, dtype=jnp.float32,
    remat: bool = False, loss_weights=None, aux_coeff: float = 0.0,
    reduce: bool = True, logits_sharding=None,
):
    from repro.models.transformer import chunked_ce

    x = _decoder_hidden(params, cfg, tokens, frames, dtype, remat)
    per_example = chunked_ce(params, cfg, x, tokens, dtype, logits_sharding)
    if loss_weights is not None:
        per_example = per_example * loss_weights
    if not reduce:
        return per_example, jnp.zeros((), jnp.float32)
    return jnp.mean(per_example), jnp.zeros((), jnp.float32)


def prefill_encdec(
    params, cfg: ModelConfig, tokens, frames, dtype=jnp.float32,
):
    enc_out = encode(params, cfg, frames, dtype)
    x = params["embed"].astype(dtype)[tokens]

    def body(x, lp):
        x, (kv, ckv) = _decoder_layer(lp, x, enc_out, cfg, dtype, return_kv=True)
        return x, (kv, ckv)

    x, ((ks, vs), (cks, cvs)) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cache = EncDecCache(
        self_attn=AttnCache(k=ks, v=vs, pos=jnp.arange(tokens.shape[1], dtype=jnp.int32)),
        cross_k=cks,
        cross_v=cvs,
    )
    return logits_from_hidden(params, cfg, x[:, -1:, :], dtype), cache


def decode_step_encdec(
    params, cfg: ModelConfig, token, cache: EncDecCache, t, dtype=jnp.float32,
):
    x = params["embed"].astype(dtype)[token]
    s_max = cache.self_attn.k.shape[2]
    slot = (t % s_max).astype(jnp.int32)
    new_pos = jax.lax.dynamic_update_slice(
        cache.self_attn.pos, t[None].astype(jnp.int32), (slot,)
    )

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        h, (ck, cv, _) = L.attention_decode(
            lp["self_attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            ck, cv, new_pos, t, dtype=dtype,
        )
        x = x + h
        x = x + L.attention_fwd(
            lp["cross_attn"], L.rmsnorm(lp["ln_x"], x, cfg.norm_eps), cfg,
            kv_override=(xk, xv), dtype=dtype, use_rope=False,
        )
        x = x + L.mlp_fwd(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps), dtype)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["layers"], cache.self_attn.k, cache.self_attn.v,
         cache.cross_k, cache.cross_v),
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = EncDecCache(
        self_attn=AttnCache(k=ks, v=vs, pos=new_pos),
        cross_k=cache.cross_k,
        cross_v=cache.cross_v,
    )
    return logits_from_hidden(params, cfg, x, dtype), new_cache
