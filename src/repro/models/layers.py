"""Layer primitives: norms, RoPE, GQA attention, SwiGLU, MoE, Mamba2 SSD.

Design: optax/flax-free. Every layer is an (init_<layer>, <layer>_fwd) pair of
pure functions over plain dict pytrees. Decode-path variants operate on a
single token against a cache (see cache.py).

All matmul-heavy ops accept a ``dtype`` for the compute precision (bf16 on
TPU); parameters are stored fp32 and cast at use (mixed precision).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# --------------------------------------------------------------------------
# activation-sharding registry
#
# The launch layer installs NamedShardings for named activation cut-points
# (trace-time state: the step builders wrap model calls in
# ``activation_shardings(...)`` so the constraints land in the traced HLO).
# Model code stays mesh-agnostic; with nothing installed this is a no-op.
#
# Names:  "residual"   — the (B, S, D) stream at every layer boundary
#         "moe_buffer" — the (G, E, C, ·) expert dispatch buffers
#         "logits"     — the (B, CHUNK, V) CE logits chunks
# --------------------------------------------------------------------------

from contextlib import contextmanager

_ACT_SHARDINGS: dict = {}


@contextmanager
def activation_shardings(**kw):
    old = dict(_ACT_SHARDINGS)
    _ACT_SHARDINGS.update(kw)
    try:
        yield
    finally:
        _ACT_SHARDINGS.clear()
        _ACT_SHARDINGS.update(old)


def constrain(x, name: str):
    s = _ACT_SHARDINGS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def constrain_tree(tree, name: str):
    """Constrain a pytree (e.g. one scanned layer's parameter slice) with a
    matching pytree of shardings. Crucially, with_sharding_constraint's
    TRANSPOSE is itself — so constraining the per-layer primal slice inside
    the scan body forces the per-layer gradient cotangent to the same
    (FSDP) sharding, turning the backward's full-tensor gradient
    all-reduces into reduce-scatters (§Perf iteration 6)."""
    specs = _ACT_SHARDINGS.get(name)
    if specs is None:
        return tree
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, specs)


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, shape, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)  # (head_dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional bias / sliding window)
# --------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, kv * dh)),
        "wv": dense_init(ks[2], (d, kv * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kv * dh,), jnp.float32)
    return p


def _qkv(params, x, cfg: ModelConfig, dtype):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"].astype(dtype)
    k = x @ params["wk"].astype(dtype)
    v = x @ params["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    return (
        q.reshape(b, s, h, dh),
        k.reshape(b, s, kv, dh),
        v.reshape(b, s, kv, dh),
    )


def attention_scores_mask(
    s_q: int, s_k: int, q_offset: int = 0, causal: bool = True,
    sliding_window: Optional[int] = None,
):
    """(s_q, s_k) boolean mask; True = attend. q position i_abs = i + q_offset."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    m = jnp.ones((s_q, s_k), bool)
    if causal:
        m = m & (kj <= qi)
    if sliding_window is not None:
        m = m & (kj > qi - sliding_window)
    return m


# query-chunked attention kicks in above this sequence length: the (S_q, S_k)
# score matrix is never materialized whole — only (Q_CHUNK, S_k) per scan step
# (flash-attention-style memory behaviour expressed in XLA; the Pallas flash
# kernel in kernels/attention is the TPU hot path).
ATTN_CHUNK_THRESHOLD = 2048
ATTN_Q_CHUNK = 1024


def _attention_core(
    q, k, v, *, causal: bool, sliding_window: Optional[int], q_offset: int,
    dtype, q_chunk: Optional[int] = None,
):
    """softmax(QKᵀ/√d)V with GQA broadcast. q: (B,Sq,KV,rep,dh); k,v: (B,Sk,KV,dh)."""
    b, sq, kvh, rep, dh = q.shape
    s_k = k.shape[1]

    def block(q_c, off):
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", q_c, k) / math.sqrt(dh)
        if causal or sliding_window is not None:
            mask = attention_scores_mask(
                q_c.shape[1], s_k, off, causal, sliding_window
            )
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
        return jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)

    if q_chunk is None and sq > ATTN_CHUNK_THRESHOLD and sq % ATTN_Q_CHUNK == 0:
        q_chunk = ATTN_Q_CHUNK
    if q_chunk is None or sq <= q_chunk or sq % q_chunk != 0:
        return block(q, q_offset)

    nc = sq // q_chunk
    qs = jnp.moveaxis(q.reshape(b, nc, q_chunk, kvh, rep, dh), 1, 0)

    @jax.checkpoint
    def body(_, inp):
        q_c, ci = inp
        return None, block(q_c, q_offset + ci * q_chunk)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, kvh, rep, dh)


def attention_fwd(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    kv_override: Optional[tuple] = None,
    return_kv: bool = False,
    dtype=jnp.float32,
    use_rope: bool = True,
):
    """Full-sequence attention (train / prefill). GQA via reshape-broadcast.

    kv_override: (k, v) of shape (B, S_kv, KV, dh) for cross-attention.
    Long sequences run query-chunked (see _attention_core) so the score
    matrix never exceeds (Q_CHUNK, S_k) per step.
    """
    b, s, _ = x.shape
    h, kv_heads, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = h // kv_heads
    q, k, v = _qkv(params, x, cfg, dtype)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    if kv_override is not None:
        k, v = kv_override
        is_causal = False
        window = None
    else:
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        is_causal = causal
        window = cfg.sliding_window
    q = q.reshape(b, s, kv_heads, rep, dh)
    out = _attention_core(
        q, k, v, causal=is_causal, sliding_window=window, q_offset=0, dtype=dtype
    ).reshape(b, s, h * dh)
    out = out @ params["wo"].astype(dtype)
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(
    params,
    x: jnp.ndarray,            # (B, 1, D) — one new token
    cfg: ModelConfig,
    cache_k: jnp.ndarray,      # (B, S_max, KV, dh)
    cache_v: jnp.ndarray,
    cache_pos: jnp.ndarray,    # (S_max,) absolute positions stored per slot (-1 empty)
    t: jnp.ndarray,            # scalar — absolute position of the new token
    *,
    dtype=jnp.float32,
    use_rope: bool = True,
    update_cache: bool = True,
):
    """Single-token decode against a (possibly ring-buffer) KV cache.

    The cache sequence dim may be sharded over the model axis — the softmax
    reduction then lowers to psum collectives under pjit (flash-decoding
    style partial-softmax merge is what XLA SPMD generates).
    """
    b, s1, _ = x.shape
    h, kv_heads, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = h // kv_heads
    s_max = cache_k.shape[1]
    q, k_new, v_new = _qkv(params, x, cfg, dtype)
    pos = jnp.full((1, 1), t)
    if use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    if update_cache:
        slot = (t % s_max).astype(jnp.int32)  # ring buffer (= t when S_max > t)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_new, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_new, (0, slot, 0, 0))
        cache_pos = jax.lax.dynamic_update_slice(cache_pos, pos[0].astype(jnp.int32), (slot,))

    # validity: slot written, causal, within window
    valid = (cache_pos >= 0) & (cache_pos <= t)
    if cfg.sliding_window is not None:
        valid = valid & (cache_pos > t - cfg.sliding_window)

    q = q.reshape(b, 1, kv_heads, rep, dh)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q, cache_k) / math.sqrt(dh)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, cache_v).reshape(b, 1, h * dh)
    out = out @ params["wo"].astype(dtype)
    return out, (cache_k, cache_v, cache_pos)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, d_ff)),
        "w_in": dense_init(ks[1], (d, d_ff)),
        "w_out": dense_init(ks[2], (d_ff, d)),
    }


def mlp_fwd(params, x, dtype=jnp.float32):
    g = jax.nn.silu(x @ params["w_gate"].astype(dtype))
    u = x @ params["w_in"].astype(dtype)
    return (g * u) @ params["w_out"].astype(dtype)


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-based GShard dispatch)
# --------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    moe = cfg.moe
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_in": dense_init(ks[2], (e, d, f)),
        "w_out": dense_init(ks[3], (e, f, d)),
    }
    if moe.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * moe.n_shared_experts)
    return p


def moe_capacity(n_tokens: int, moe) -> int:
    cap = int(math.ceil(n_tokens * moe.top_k / moe.n_experts * moe.capacity_factor))
    return max(cap, moe.top_k)


MOE_GROUP_SIZE = 1024  # routing-group size (GShard "G"); capacity is per group


def _moe_group_size(n_tok: int) -> int:
    gs = min(MOE_GROUP_SIZE, n_tok)
    while n_tok % gs:
        gs -= 1
    return gs


def moe_fwd(params, x, cfg: ModelConfig, dtype=jnp.float32):
    """Capacity-limited top-k MoE with scatter/gather dispatch.

    Tokens are processed in routing groups of ≤ MOE_GROUP_SIZE with per-group
    capacity C = ceil(gs·k·cf/E), so dispatch memory is O(G·E·C·D) = O(T·k·cf·D)
    and dispatch *compute* is O(T·k·D) scatter/gather moves — NOT the
    O(T·E·C·D) of the one-hot einsum formulation, which at production token
    counts (10⁶ tokens) would dwarf the expert FLOPs themselves.

    Returns (out, aux_loss). x: (B, S, D).
    """
    moe = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    e, k = moe.n_experts, moe.top_k
    gs = _moe_group_size(n_tok)
    n_groups = n_tok // gs
    cap = moe_capacity(gs, moe)

    xt = x.reshape(n_groups, gs, d)
    logits = (xt @ params["router"].astype(dtype)).astype(jnp.float32)  # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, gs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (over all tokens)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0].reshape(-1), e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # position of each (token, slot) within its expert, per group.
    # Slot-major cumsum (k outer, token inner) so the per-k scatters below
    # see consistent positions.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (G, gs, k, E)
    flat = jnp.moveaxis(onehot, 2, 1).reshape(n_groups, k * gs, e)  # k-major
    pos = jnp.cumsum(flat, axis=1) * flat - 1               # (G, k*gs, E)
    pos_tok = jnp.max(pos, axis=-1).reshape(n_groups, k, gs)  # ≥ -1
    e_tok = jnp.moveaxis(gate_idx, 2, 1)                    # (G, k, gs)
    within = (pos_tok >= 0) & (pos_tok < cap)
    # overflow → index `cap`, dropped by scatter mode="drop"
    pos_safe = jnp.where(within, pos_tok, cap)

    # dispatch: k sequential scatter-adds of (G, gs, D) — NEVER materializes
    # the k×-duplicated (G, gs·k, D) token tensor (≈ 6 GB/dev at olmoe's
    # top-8, 1M tokens; §Perf iteration 4)
    def scatter_k(xg, e_g, p_g):
        buf = jnp.zeros((e, cap, d), dtype)
        for kk in range(k):
            buf = buf.at[e_g[kk], p_g[kk]].add(xg, mode="drop")
        return buf

    xe = jax.vmap(scatter_k)(xt, e_tok, pos_safe)  # (G, E, C, D)
    xe = constrain(xe, "moe_buffer")  # expert-parallel: E over "model"

    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(dtype)))
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_in"].astype(dtype))
    ye = jnp.einsum("gecf,efd->gecd", g * u, params["w_out"].astype(dtype))
    ye = constrain(ye, "moe_buffer")

    gv = (jnp.moveaxis(gate_vals, 2, 1) * within).astype(dtype)  # (G, k, gs)

    def gather_k(ye_g, e_g, p_g, gv_g):
        out = jnp.zeros((gs, d), dtype)
        for kk in range(k):
            vals = ye_g.at[e_g[kk], p_g[kk]].get(mode="fill", fill_value=0)
            out = out + vals * gv_g[kk][:, None]
        return out

    out = jax.vmap(gather_k)(ye, e_tok, pos_safe, gv).reshape(b, s, d)

    if moe.n_shared_experts:
        out = out + mlp_fwd(params["shared"], x, dtype)
    return out, aux


# --------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, arXiv:2405.21060)
# --------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    n = s.d_state
    ks = jax.random.split(key, 5)
    # in_proj → [z(di), x(di), B(n), C(n), dt(nh)]  (single B/C group)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + nh)),
        "conv_w": dense_init(ks[1], (s.conv_kernel, di + 2 * n), scale=0.5),
        "conv_b": jnp.zeros((di + 2 * n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _split_mamba_proj(zxbcdt, di, n, nh):
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt


def causal_conv1d(xbc, w, b):
    """Depthwise causal conv over the sequence dim. xbc: (B, S, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return out + b


def ssd_chunked(xdt, la, B, C, chunk: int):
    """Chunked SSD scan (pure-jnp; kernels/ssd has the Pallas version).

    Args:
      xdt: (b, s, h, p)  — dt-scaled inputs
      la:  (b, s, h)     — log decay  (la = -exp(A_log)·dt ≤ 0)
      B:   (b, s, n)     — input projections  (single group, shared over heads)
      C:   (b, s, n)     — output projections
    Returns y: (b, s, h, p)
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    q = chunk
    xdt = xdt.reshape(b, c, q, h, p)
    la = la.reshape(b, c, q, h)
    Bc = B.reshape(b, c, q, n)
    Cc = C.reshape(b, c, q, n)

    La = jnp.cumsum(la, axis=2)  # (b,c,q,h) inclusive cumulative log decay
    # --- intra-chunk (quadratic within chunk; the MXU-friendly part)
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (b,c,q,q)
    # decay matrix exp(La_i - La_j) for i >= j. Mask diff BEFORE the exp:
    # exp of a large positive (upper-triangle) diff is inf, and inf·0 = NaN
    # in the backward pass of a post-exp where().
    diff = La[:, :, :, None, :] - La[:, :, None, :, :]  # (b,c,q,k,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    M = G[..., None] * decay  # (b,c,q,k,h)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xdt)

    # --- chunk-boundary states
    seg = jnp.exp(La[:, :, -1:, :] - La)  # (b,c,q,h): decay from t to chunk end
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", seg, Bc, xdt)  # (b,c,h,n,p)
    chunk_decay = jnp.exp(La[:, :, -1, :])  # (b,c,h)

    def scan_fn(carry, inp):
        s_c, dec = inp
        # keep the recurrent state in f32: exp(La) is f32 and the decay
        # product must not round through bf16 across chunks
        new = dec[..., None, None] * carry + s_c.astype(jnp.float32)
        return new, carry  # emit the state *entering* the chunk

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, S_prev = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prev = jnp.moveaxis(S_prev, 0, 1)  # (b,c,h,n,p) state entering each chunk

    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, S_prev, jnp.exp(La))
    return (y_intra + y_inter).astype(xdt.dtype).reshape(b, s, h, p)


def mamba2_fwd(params, x, cfg: ModelConfig, dtype=jnp.float32, chunk=None):
    """Full-sequence Mamba2 block (train / prefill). x: (B, S, D)."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    n = s_cfg.d_state
    p_dim = s_cfg.head_dim
    chunk = chunk or s_cfg.chunk_size

    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xbc, dt = _split_mamba_proj(zxbcdt, di, n, nh)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype)))
    xin = xbc[..., :di]
    B = xbc[..., di : di + n]
    C = xbc[..., di + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    la = -jnp.exp(params["A_log"])[None, None, :] * dt  # log decay
    xh = xin.reshape(*xin.shape[:-1], nh, p_dim)
    xdt = xh * dt[..., None].astype(dtype)

    y = ssd_chunked(xdt, la.astype(jnp.float32), B, C, chunk)
    y = y + params["D"].astype(dtype)[None, None, :, None] * xh
    y = y.reshape(*y.shape[:-2], di)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dtype)


def mamba2_decode(params, x, cfg: ModelConfig, ssm_state, conv_state, dtype=jnp.float32):
    """Single-token recurrent step. x: (B, 1, D).

    ssm_state: (B, H, N, P); conv_state: (B, K-1, di+2n).
    """
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    n = s_cfg.d_state
    p_dim = s_cfg.head_dim
    k = s_cfg.conv_kernel

    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xbc, dt = _split_mamba_proj(zxbcdt, di, n, nh)  # (B,1,·)

    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"].astype(dtype)) + params[
        "conv_b"
    ].astype(dtype)
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv_state = window[:, 1:, :]

    xin = xbc1[..., :di]
    B = xbc1[..., di : di + n][:, 0]  # (B, n)
    C = xbc1[..., di + n :][:, 0]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    a = jnp.exp(-jnp.exp(params["A_log"])[None] * dt)  # (B,nh)
    xh = xin[:, 0].reshape(-1, nh, p_dim)
    xdt = xh * dt[..., None].astype(dtype)

    new_state = a[..., None, None].astype(dtype) * ssm_state + jnp.einsum(
        "bn,bhp->bhnp", B, xdt
    )
    y = jnp.einsum("bn,bhnp->bhp", C, new_state) + params["D"].astype(dtype)[None, :, None] * xh
    y = y.reshape(-1, 1, di)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dtype), new_state, new_conv_state
