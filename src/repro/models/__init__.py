from repro.models.api import model_decode, model_init, model_loss, model_prefill
from repro.models.cache import init_cache
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "init_cache",
    "model_decode",
    "model_init",
    "model_loss",
    "model_prefill",
]
