"""Unified model API dispatching on architecture family.

batch dict keys: "tokens" always; "embeds" for VLM patch embeddings;
"frames" for audio frame embeddings (enc-dec).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.cache import init_cache
from repro.models.config import ModelConfig


def model_init(cfg: ModelConfig, key):
    if cfg.arch_type == "encdec":
        return encdec.init_encdec(cfg, key)
    return transformer.init_model(cfg, key)


def model_loss(
    params, cfg: ModelConfig, batch: dict, dtype=jnp.float32,
    remat: bool = False, loss_weights=None, reduce: bool = True,
    logits_sharding=None, aux_coeff: float = 0.01,
):
    """Returns (loss, aux); with reduce=False, (per_example (B,), aux)."""
    if cfg.arch_type == "encdec":
        return encdec.encdec_loss(
            params, cfg, batch["tokens"], batch["frames"], dtype, remat,
            loss_weights=loss_weights, reduce=reduce,
            logits_sharding=logits_sharding, aux_coeff=aux_coeff,
        )
    return transformer.lm_loss(
        params, cfg, batch["tokens"], batch.get("embeds"), dtype, remat,
        loss_weights=loss_weights, reduce=reduce,
        logits_sharding=logits_sharding, aux_coeff=aux_coeff,
    )


def model_prefill(params, cfg: ModelConfig, batch: dict, dtype=jnp.float32):
    if cfg.arch_type == "encdec":
        return encdec.prefill_encdec(params, cfg, batch["tokens"], batch["frames"], dtype)
    return transformer.prefill(params, cfg, batch["tokens"], batch.get("embeds"), dtype)


def model_decode(params, cfg: ModelConfig, token, cache, t, dtype=jnp.float32):
    if cfg.arch_type == "encdec":
        return encdec.decode_step_encdec(params, cfg, token, cache, t, dtype)
    return transformer.decode_step(params, cfg, token, cache, t, dtype)


__all__ = ["model_init", "model_loss", "model_prefill", "model_decode", "init_cache"]
