"""npz-based checkpointing of (possibly sharded) pytrees.

Flat key scheme: pytree paths are serialized as '/'-joined strings
(dict keys, NamedTuple fields, sequence indices). Sharded arrays are
gathered to host before writing (fully-addressable process assumption —
single-controller CPU/TPU-pod runtime); restore re-shards by placing
leaves onto the shardings of a template pytree when given.

Writes are CRASH-ATOMIC: the npz is fully written (and fsynced) to a
tmp file in the target directory, the ``.meta.json`` sidecar is published
first, and only then is the npz renamed into place with ``os.replace`` —
the npz is the COMMIT POINT. A process killed mid-save can therefore never
leave a torn npz at the published path (``sim.resilience`` discovers
checkpoints by npz presence, so a visible checkpoint always has both a
complete npz and its metadata), and a truncated file written by any other
means fails ``load_pytree`` loudly instead of half-reading.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _paths(path: str) -> tuple[str, str]:
    """Normalize ``path`` (with or without the ``.npz`` suffix) to the
    published ``(npz_path, meta_path)`` pair — one rule for save and
    restore, so the sidecar is always found where it was written."""
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".npz", base + ".meta.json"


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    """Atomically persist ``tree`` (and optional ``metadata``) at ``path``.

    Write order is the crash-safety contract: (1) the full npz streams into
    a same-directory tmp file and is fsynced, (2) the ``.meta.json`` sidecar
    is atomically published, (3) ``os.replace`` commits the npz. A kill at
    any point leaves either no published npz (steps 1-2: at worst a stale
    ``*.tmp-<pid>`` file and an orphan sidecar, both harmless) or a complete
    checkpoint — never a torn npz under the published name.
    """
    flat = _flatten_with_paths(tree)
    npz_path, meta_path = _paths(path)
    os.makedirs(os.path.dirname(os.path.abspath(npz_path)), exist_ok=True)
    tmp = f"{npz_path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        if metadata is not None:
            meta_tmp = f"{meta_path}.tmp-{os.getpid()}"
            with open(meta_tmp, "w") as f:
                json.dump(metadata, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(meta_tmp, meta_path)
        os.replace(tmp, npz_path)
    except BaseException:
        # never leave the tmp behind on a failed save (a crash can — it is
        # ignored by discovery either way)
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def load_pytree(path: str, template) -> Any:
    """Restore into the structure (and shardings, if any) of ``template``."""
    path = _paths(path)[0]
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_p:
        key = "/".join(_path_str(k) for k in p)
        arr = data[key]
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            arr = jax.device_put(arr, leaf.sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def save(path: str, step: int, params, opt_state=None, extra: Optional[dict] = None):
    """Save a full training state."""
    state = {"params": params}
    if opt_state is not None:
        state["opt_state"] = opt_state
    save_pytree(path, state, metadata={"step": step, **(extra or {})})


def restore(path: str, params_template, opt_template=None):
    """Returns (step, params, opt_state)."""
    state_t = {"params": params_template}
    if opt_template is not None:
        state_t["opt_state"] = opt_template
    state = load_pytree(path, state_t)
    meta_path = _paths(path)[1]
    step = 0
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            step = json.load(f).get("step", 0)
    return step, state["params"], state.get("opt_state")
