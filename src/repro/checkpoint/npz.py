"""npz-based checkpointing of (possibly sharded) pytrees.

Flat key scheme: pytree paths are serialized as '/'-joined strings
(dict keys, NamedTuple fields, sequence indices). Sharded arrays are
gathered to host before writing (fully-addressable process assumption —
single-controller CPU/TPU-pod runtime); restore re-shards by placing
leaves onto the shardings of a template pytree when given.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    flat = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def load_pytree(path: str, template) -> Any:
    """Restore into the structure (and shardings, if any) of ``template``."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_p:
        key = "/".join(_path_str(k) for k in p)
        arr = data[key]
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            arr = jax.device_put(arr, leaf.sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def save(path: str, step: int, params, opt_state=None, extra: Optional[dict] = None):
    """Save a full training state."""
    state = {"params": params}
    if opt_state is not None:
        state["opt_state"] = opt_state
    save_pytree(path, state, metadata={"step": step, **(extra or {})})


def restore(path: str, params_template, opt_template=None):
    """Returns (step, params, opt_state)."""
    state_t = {"params": params_template}
    if opt_template is not None:
        state_t["opt_state"] = opt_template
    state = load_pytree(path, state_t)
    meta_path = (path if path.endswith(".npz") else path + ".npz") + ".meta.json"
    meta_path = meta_path.replace(".npz.meta.json", ".meta.json")
    step = 0
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            step = json.load(f).get("step", 0)
    return step, state["params"], state.get("opt_state")
