"""repro.obs — the unified flight-recorder.

Three layers, all opt-in and zero-cost when off:

  * host side (:mod:`repro.obs.spans`, :mod:`repro.obs.registry`): timing
    spans and a typed counter/gauge registry that the engine's scattered
    ad-hoc counters collapsed into, streaming JSONL events per process via
    ``REPRO_OBS_DIR`` (:mod:`repro.obs.sink`);
  * in-trace (:class:`ObsConfig` + :class:`repro.core.metrics.RoundDiagnostics`):
    cheap per-round scalar taps computed INSIDE the compiled lattice program,
    gated by a static flag that joins the engine cache key;
  * reporting (:mod:`repro.obs.report`, :mod:`repro.obs.profile`):
    ``python -m repro.obs.report`` renders a run's JSONL into summary tables
    and CI gates; ``REPRO_OBS_PROFILE=1`` captures ``jax.profiler`` traces.

No module here imports jax at import time — obs sits below ``repro.sim`` in
the layering and stays safe to import before distributed backend init.
"""
from repro.obs.config import DEFAULT_OBS, ObsConfig
from repro.obs.profile import maybe_profile, profiling_enabled
from repro.obs.registry import (
    Counter,
    Gauge,
    counter,
    counter_add,
    gauge,
    gauge_set,
    metric_value,
    metrics_snapshot,
    reset_metrics,
)
from repro.obs.sink import (
    ENV_OBS_DIR,
    ENV_OBS_PROFILE,
    close_sink,
    emit,
    event_files,
    obs_dir,
    process_coords,
    read_events,
)
from repro.obs.spans import span, span_totals

__all__ = [
    "ObsConfig",
    "DEFAULT_OBS",
    "span",
    "span_totals",
    "Counter",
    "Gauge",
    "counter",
    "gauge",
    "counter_add",
    "gauge_set",
    "metric_value",
    "metrics_snapshot",
    "reset_metrics",
    "emit",
    "obs_dir",
    "process_coords",
    "read_events",
    "event_files",
    "close_sink",
    "maybe_profile",
    "profiling_enabled",
    "ENV_OBS_DIR",
    "ENV_OBS_PROFILE",
]
