"""Render a run's JSONL event sink into a human-readable summary.

    PYTHONPATH=src python -m repro.obs.report [DIR] [--gate-warm-lattice]

``DIR`` defaults to ``$REPRO_OBS_DIR``. The report groups by process
(multihost runs write one file per worker) and summarizes:

  * spans            — count / total / mean seconds per span name
  * counters         — final totals (engine cache, compiles, traces, …)
  * lattice runs     — per ``run_lattice`` call: cells, cold/warm,
                       trace and compile deltas
  * diagnostics taps — per-round means of the in-trace ``ObsConfig``
                       diagnostics (aggregation noise power, scheduling
                       entropy, eps clamps, gradient-norm spread)

``--gate-warm-lattice`` turns the report into a CI smoke gate (exit 1 on
violation): every warm lattice call (one whose engine had already traced)
must record ZERO re-traces and ZERO new compiles, and no fused lattice
engine may ever accumulate more than one compiled program — the pipeline
version of the test-local retrace assertions.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.obs.sink import event_files, obs_dir, read_events


def collect(events) -> dict:
    """Fold an event stream into per-process summary structures."""
    out: dict = {
        "spans": defaultdict(lambda: {"count": 0, "seconds": 0.0, "max": 0.0}),
        "counters": {},  # (process, name) -> last seen total
        "gauges": {},
        "lattice": [],
        "diag": [],
        "profiles": [],
        "processes": set(),
    }
    for ev in events:
        proc = ev.get("process_index", 0)
        out["processes"].add(proc)
        kind = ev.get("kind")
        name = ev.get("name", "?")
        if kind == "span":
            s = out["spans"][(proc, name)]
            s["count"] += 1
            s["seconds"] += ev.get("seconds", 0.0)
            s["max"] = max(s["max"], ev.get("seconds", 0.0))
        elif kind == "counter":
            out["counters"][(proc, name)] = ev.get("total", 0)
        elif kind == "gauge":
            out["gauges"][(proc, name)] = ev.get("value")
        elif kind == "lattice":
            out["lattice"].append(ev)
        elif kind == "diag":
            out["diag"].append(ev)
        elif kind == "profile":
            out["profiles"].append(ev)
    return out


def _fmt_rounds(values, head: int = 6) -> str:
    vals = list(values)
    shown = ", ".join(f"{v:.3e}" for v in vals[:head])
    return f"[{shown}{', …' if len(vals) > head else ''}]"


def render(summary: dict) -> str:
    lines: list[str] = []
    procs = sorted(summary["processes"]) or [0]
    lines.append(
        f"# repro.obs report — {len(procs)} process(es): {procs}"
    )

    if summary["spans"]:
        lines.append("\n## spans (host wall-clock)")
        lines.append(f"{'process':>7}  {'span':<28} {'count':>6} "
                     f"{'total_s':>9} {'mean_s':>9} {'max_s':>9}")
        for (proc, name), s in sorted(summary["spans"].items()):
            mean = s["seconds"] / max(s["count"], 1)
            lines.append(
                f"{proc:>7}  {name:<28} {s['count']:>6} "
                f"{s['seconds']:>9.3f} {mean:>9.3f} {s['max']:>9.3f}"
            )

    if summary["counters"]:
        lines.append("\n## counters (final totals)")
        lines.append(f"{'process':>7}  {'counter':<32} {'total':>12}")
        for (proc, name), total in sorted(summary["counters"].items()):
            shown = f"{total:.3f}" if isinstance(total, float) else str(total)
            lines.append(f"{proc:>7}  {name:<32} {shown:>12}")

    if summary["lattice"]:
        lines.append("\n## lattice runs (cold/warm compile behavior)")
        lines.append(f"{'process':>7} {'cells':>6} {'rounds':>7} {'fused':>6} "
                     f"{'warm':>5} {'trace_Δ':>8} {'compile_Δ':>10} "
                     f"{'engine_compiles':>16}")
        for ev in summary["lattice"]:
            lines.append(
                f"{ev.get('process_index', 0):>7} {ev.get('cells', '?'):>6} "
                f"{ev.get('n_rounds', '?'):>7} "
                f"{str(bool(ev.get('fused'))):>6} "
                f"{str(bool(ev.get('warm'))):>5} "
                f"{ev.get('trace_delta', '?'):>8} "
                f"{ev.get('compile_delta', '?'):>10} "
                f"{ev.get('engine_compiles', '?'):>16}"
            )

    if summary["diag"]:
        lines.append("\n## in-trace diagnostics (per-round means over cells)")
        for ev in summary["diag"]:
            lines.append(
                f"process {ev.get('process_index', 0)} — "
                f"{ev.get('name')} ({ev.get('n_rounds', '?')} rounds)"
            )
            for tap, series in (ev.get("taps") or {}).items():
                mean = sum(series) / max(len(series), 1)
                lines.append(
                    f"  {tap:<20} mean={mean:.4e}  rounds={_fmt_rounds(series)}"
                )

    if summary["profiles"]:
        lines.append("\n## profiler captures")
        for ev in summary["profiles"]:
            lines.append(f"  {ev.get('name')}: {ev.get('trace_dir')}")
    return "\n".join(lines)


def gate_warm_lattice(summary: dict) -> list[str]:
    """The CI smoke-gate predicate. Returns human-readable violations.

    * a WARM lattice call (engine had already traced) must re-trace zero
      times and compile zero new programs;
    * a fused lattice engine must never hold more than one compiled program
      (``n_compiles > 1`` means the one-compile contract broke).
    """
    problems = []
    if not summary["lattice"]:
        problems.append("no lattice events recorded — nothing to gate")
    for ev in summary["lattice"]:
        where = (f"process {ev.get('process_index', 0)} "
                 f"({ev.get('cells', '?')} cells)")
        if ev.get("warm"):
            if ev.get("trace_delta", 0):
                problems.append(
                    f"{where}: warm lattice repeat re-traced "
                    f"{ev['trace_delta']} time(s)"
                )
            if ev.get("compile_delta", 0):
                problems.append(
                    f"{where}: warm lattice repeat compiled "
                    f"{ev['compile_delta']} new program(s)"
                )
        if ev.get("fused") and ev.get("engine_compiles", 0) > 1:
            problems.append(
                f"{where}: fused lattice engine holds "
                f"{ev['engine_compiles']} compiled programs (expected 1)"
            )
    return problems


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "dir", nargs="?", default=None,
        help="sink directory (default: $REPRO_OBS_DIR)",
    )
    parser.add_argument(
        "--gate-warm-lattice", action="store_true",
        help="exit 1 unless every warm lattice repeat recorded zero "
        "re-traces/compiles and fused engines hold one program",
    )
    args = parser.parse_args(argv)
    path = args.dir or obs_dir()
    if not path:
        parser.error("no sink directory: pass DIR or set REPRO_OBS_DIR")
    files = event_files(path)
    if not files:
        print(f"no obs event files under {path}", file=sys.stderr)
        return 1
    summary = collect(read_events(path))
    print(render(summary))
    if args.gate_warm_lattice:
        problems = gate_warm_lattice(summary)
        if problems:
            print("\nGATE FAILED:", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("\ngate ok: warm lattice repeats re-traced zero times, "
              "one compile per fused engine")
    return 0


if __name__ == "__main__":
    sys.exit(main())
