"""Host-side timing spans: ``with span("lattice.compile"): ...``.

A span measures one wall-clock interval, accumulates it into the registry
(``span.<name>.count`` / ``span.<name>.seconds`` — so totals are queryable
in-process without replaying the sink) and streams one ``span`` event per
exit to the JSONL sink. Usable as a context manager or a decorator
(:class:`span` subclasses ``ContextDecorator``).

Spans are HOST-side: they time Python-visible work (trace, AOT compile,
dispatch, stream-out), never device execution — for that, set
``REPRO_OBS_PROFILE=1`` (``repro.obs.profile``) and read the captured
``jax.profiler`` trace.
"""
from __future__ import annotations

import time
from contextlib import ContextDecorator

from repro.obs.registry import counter_add
from repro.obs.sink import emit


class span(ContextDecorator):
    """Time one interval under a dotted name, with optional static fields.

    ``fields`` are attached to the emitted event verbatim (keep them
    JSON-serializable scalars); :meth:`annotate` adds more from inside the
    block. Exceptions propagate — the span still records, stamped with
    ``error`` — so instrumenting a call site never changes its control flow.
    """

    def __init__(self, name: str, **fields):
        self.name = name
        self.fields = fields
        self.seconds: float | None = None  # set on exit
        self._t0: float | None = None

    def annotate(self, **fields) -> "span":
        self.fields.update(fields)
        return self

    def __enter__(self) -> "span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.seconds = time.perf_counter() - self._t0
        counter_add(f"span.{self.name}.count", 1, emit_event=False)
        counter_add(f"span.{self.name}.seconds", self.seconds, emit_event=False)
        if exc_type is not None:
            self.fields.setdefault("error", exc_type.__name__)
        emit("span", self.name, seconds=round(self.seconds, 6), **self.fields)
        return False  # never swallow exceptions


def span_totals(name: str) -> dict:
    """In-process totals for one span name: ``{"count", "seconds"}``."""
    from repro.obs.registry import metric_value

    return {
        "count": metric_value(f"span.{name}.count"),
        "seconds": metric_value(f"span.{name}.seconds"),
    }
