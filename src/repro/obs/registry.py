"""The in-process metrics registry: typed counters and gauges.

One flat namespace of dotted metric names (``engine_cache.hits``,
``lattice.n_compiles``, ``span.lattice.compile.seconds``) holding plain
numbers. This registry is what the engine's five generations of ad-hoc
counters collapsed into: ``repro.sim.engine.engine_cache_stats``,
``repro.sim.compile_cache.persistent_cache_counters`` and friends are now
thin shims reading it, and every mutation can stream to the JSONL sink
(``repro.obs.sink``) so a run's counter history is replayable offline.

Reset semantics — the part the old scattered counters never agreed on:

  * :func:`reset_metrics` with a ``prefix`` zeroes exactly that namespace
    (``reset_engine_cache`` resets ``engine_cache.``, nothing else);
  * :func:`reset_metrics` with no prefix zeroes everything — including the
    persistent-compile-cache counters, so a CI warm-run guard
    (``REPRO_COMPILE_CACHE_EXPECT_HITS``) should never share a process with
    an unscoped full reset (tests use prefix resets).

No jax imports; safe from anywhere.
"""
from __future__ import annotations

import threading
from typing import Union

from repro.obs.sink import emit

Number = Union[int, float]

_METRICS: dict[str, Number] = {}
# increments can fire from jitted-function trace bodies and listener
# callbacks; keep them atomic under any threaded caller
_LOCK = threading.Lock()


def counter_add(name: str, delta: Number = 1, emit_event: bool = True) -> Number:
    """Add ``delta`` to counter ``name`` (created at 0) and return the new
    total. Streams a ``counter`` event to the sink unless ``emit_event`` is
    False (span bookkeeping passes False — the span event already carries
    the same numbers)."""
    with _LOCK:
        total = _METRICS.get(name, 0) + delta
        _METRICS[name] = total
    if emit_event:
        emit("counter", name, delta=delta, total=total)
    return total


def gauge_set(name: str, value: Number, emit_event: bool = True) -> Number:
    """Set gauge ``name`` to ``value`` (last write wins)."""
    with _LOCK:
        _METRICS[name] = value
    if emit_event:
        emit("gauge", name, value=value)
    return value


def metric_value(name: str, default: Number = 0) -> Number:
    """Current value of one metric (``default`` when never touched)."""
    return _METRICS.get(name, default)


def metrics_snapshot(prefix: str = "") -> dict:
    """Copy of every metric whose name starts with ``prefix``."""
    with _LOCK:
        return {k: v for k, v in _METRICS.items() if k.startswith(prefix)}


def reset_metrics(prefix: str = "") -> None:
    """Zero (drop) every metric under ``prefix``; no prefix drops all."""
    with _LOCK:
        for k in [k for k in _METRICS if k.startswith(prefix)]:
            del _METRICS[k]


class Counter:
    """Typed handle on one monotonically-increasing registry counter."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def add(self, delta: Number = 1) -> Number:
        return counter_add(self.name, delta)

    @property
    def value(self) -> Number:
        return metric_value(self.name)


class Gauge:
    """Typed handle on one last-write-wins registry gauge."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def set(self, value: Number) -> Number:
        return gauge_set(self.name, value)

    @property
    def value(self) -> Number:
        return metric_value(self.name)


def counter(name: str) -> Counter:
    """A :class:`Counter` handle for ``name`` (registered lazily at first add)."""
    return Counter(name)


def gauge(name: str) -> Gauge:
    """A :class:`Gauge` handle for ``name``."""
    return Gauge(name)
