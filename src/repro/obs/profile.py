"""Opt-in ``jax.profiler`` capture around lattice dispatches.

``REPRO_OBS_PROFILE=1`` (any value but ``0``/empty) makes
:func:`maybe_profile` wrap its block in ``jax.profiler.trace``, writing the
capture under ``$REPRO_OBS_DIR/profile/<tag>/`` (or ``./repro-obs/profile``
when no sink dir is set) and emitting a ``profile`` event pointing at it.
Off — the default — it is a zero-cost passthrough: no jax import, no env
beyond one lookup.

The engine wraps :meth:`SimEngine.run_lattice_cells` with this, so a single

    REPRO_OBS_PROFILE=1 REPRO_OBS_DIR=/tmp/obs python examples/sim_lattice.py

yields a TensorBoard-loadable trace of the real lattice program alongside
the JSONL events describing the same run.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

from repro.obs.sink import ENV_OBS_PROFILE, emit, obs_dir


def profiling_enabled() -> bool:
    """True when ``REPRO_OBS_PROFILE`` asks for profiler captures."""
    return os.environ.get(ENV_OBS_PROFILE, "") not in ("", "0")


@contextmanager
def maybe_profile(tag: str):
    """Capture a ``jax.profiler`` trace of the block when enabled; no-op
    otherwise. Never raises out of profiler setup — a broken profiler must
    not take the actual computation down with it."""
    if not profiling_enabled():
        yield
        return
    base = obs_dir() or os.path.abspath("repro-obs")
    trace_dir = os.path.join(base, "profile", tag)
    os.makedirs(trace_dir, exist_ok=True)
    import jax

    try:
        ctx = jax.profiler.trace(trace_dir)
    except Exception:  # pragma: no cover - profiler unavailable
        yield
        return
    with ctx:
        yield
    emit("profile", tag, trace_dir=trace_dir)
