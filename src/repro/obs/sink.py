"""The per-process JSONL event sink behind every obs span/counter/gauge.

One env contract, mirroring ``REPRO_COMPILE_CACHE``:

    REPRO_OBS_DIR=<dir>      stream every obs event into <dir> as JSONL
    REPRO_OBS_PROFILE=1      additionally capture jax.profiler traces around
                             lattice dispatches (see ``repro.obs.profile``)

When ``REPRO_OBS_DIR`` is unset, :func:`emit` still returns the assembled
event (the in-memory registry keeps working) but writes nothing — the
default path costs one env lookup per event.

Multihost: every event is stamped with this process's index/count, read from
the ``REPRO_DIST_*`` env contract that ``repro.launch.distributed`` writes
into each worker (deliberately NOT from ``jax.process_index()`` — the sink
must never be the thing that initializes the jax backend, and the env
contract is available before ``initialize_distributed`` runs). Each process
appends to its own file, ``events-p<index>of<count>-<pid>.jsonl``, so an
N-worker launcher run under one shared ``REPRO_OBS_DIR`` produces exactly
one file per worker and no cross-process write interleaving.

This module imports no jax: it is safe to import from anywhere, including
``repro.sim.multihost`` (which must stay import-safe before backend init).
"""
from __future__ import annotations

import io
import json
import os
import time
from typing import Iterator, TextIO

ENV_OBS_DIR = "REPRO_OBS_DIR"
ENV_OBS_PROFILE = "REPRO_OBS_PROFILE"

# the multihost env contract (literals duplicated from repro.sim.multihost:
# obs sits BELOW sim in the layering and must not import it)
_ENV_PROCESS_ID = "REPRO_DIST_PROCESS_ID"
_ENV_NUM_PROCESSES = "REPRO_DIST_NUM_PROCESSES"


def obs_dir() -> str | None:
    """The sink directory from ``$REPRO_OBS_DIR``; None when unset."""
    path = os.environ.get(ENV_OBS_DIR) or None
    if not path:
        return None
    return os.path.abspath(os.path.expanduser(path))


def process_coords() -> tuple[int, int]:
    """(process_index, process_count) from the ``REPRO_DIST_*`` env contract
    (0, 1) outside a distributed run — never touches the jax backend."""
    try:
        idx = int(os.environ.get(_ENV_PROCESS_ID) or 0)
        count = int(os.environ.get(_ENV_NUM_PROCESSES) or 1)
    except ValueError:
        return 0, 1
    return idx, max(count, 1)


# one appending handle per sink directory (a process writes one file per dir)
_HANDLES: dict[str, TextIO] = {}


def _handle(path: str) -> TextIO:
    h = _HANDLES.get(path)
    if h is None or h.closed:
        os.makedirs(path, exist_ok=True)
        idx, count = process_coords()
        name = f"events-p{idx:03d}of{count:03d}-{os.getpid()}.jsonl"
        # line-buffered on top of emit()'s per-event flush: a worker killed
        # mid-stream (SIGKILL, os._exit fault injection) leaves at worst one
        # torn trailing line, which read_events skips — every completed event
        # line survives the writer
        h = _HANDLES[path] = open(
            os.path.join(path, name), "a", buffering=1, encoding="utf-8"
        )
    return h


def emit(kind: str, name: str, **fields) -> dict:
    """Assemble (and, when the sink is active, persist) one obs event.

    Every event carries a wall-clock timestamp, the emitting process's
    index/count (multihost stamp) and pid, plus the caller's fields. Lines
    are flushed immediately: a crashed worker's events survive it.
    """
    idx, count = process_coords()
    event = {
        "ts": round(time.time(), 6),
        "kind": kind,
        "name": name,
        "process_index": idx,
        "process_count": count,
        "pid": os.getpid(),
        **fields,
    }
    path = obs_dir()
    if path:
        h = _handle(path)
        h.write(json.dumps(event) + "\n")
        h.flush()
    return event


def close_sink() -> None:
    """Close every open sink handle (test hygiene; reopens lazily)."""
    for h in _HANDLES.values():
        if not h.closed:
            h.close()
    _HANDLES.clear()


def event_files(path: str) -> list[str]:
    """The sink's event files under ``path``, sorted by name (= by process
    index, then pid)."""
    if not os.path.isdir(path):
        return []
    return sorted(
        os.path.join(path, n)
        for n in os.listdir(path)
        if n.startswith("events-") and n.endswith(".jsonl")
    )


def read_events(path: str) -> Iterator[dict]:
    """Yield every event recorded under sink directory ``path`` (all
    processes' files, file order then line order). Malformed lines — e.g. a
    line torn by a killed worker — are skipped, not raised."""
    for fname in event_files(path):
        with io.open(fname, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    continue
