"""Static observability configuration for the traced engine programs.

:class:`ObsConfig` is hashable and frozen because it is part of the engine
cache key (``repro.sim.engine.cached_engine``): flipping ``diagnostics``
selects a DIFFERENT traced program (extra per-round tap ops and extra
record leaves), so it must never replay a trace built under the other
setting. With ``diagnostics=False`` — the default everywhere — the engine
compiles exactly the same program as before this subsystem existed: zero
new ops, bit-identical pinned trajectories.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """What the traced engine programs record beyond the base round record.

    diagnostics: compute the cheap per-round scalar taps
      (:class:`repro.core.metrics.RoundDiagnostics` — aggregation noise
      power after reweighting, scheduling-probability entropy, eps-guard
      clamp count, gradient-norm spread) inside the compiled program and
      carry them in the record pytree. Off (default): the record pytree and
      the program are bit-identical to the uninstrumented engine.
    """

    diagnostics: bool = False


# the default (everything off) — module-level so identity comparisons and
# cache keys share one object
DEFAULT_OBS = ObsConfig()
