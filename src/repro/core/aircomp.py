"""AirComp signal chain (paper Sec. II-B).

Implements, in pure JAX (a fused Pallas kernel lives in kernels/aircomp):

  * gradient normalization into unit-variance symbols          (Eq. 5)
  * optimal transceiver design under per-device power budget   (Lemma 1)
  * the noisy superposed aggregation                           (Eq. 16)
  * the closed-form communication distortion                   (Eq. 15)

All functions operate on *stacked* per-device gradients ``g`` of shape
``(n_devices, D)`` plus per-device scalars; masking selects the scheduled
set S^t (masked devices transmit nothing).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.numerics import eps_guard, safe_div


class GradStats(NamedTuple):
    """Per-device first/second moments of the local gradient (Sec. II-B)."""

    mean: jnp.ndarray  # M_i^t, (n_devices,)
    var: jnp.ndarray   # V_i^t, (n_devices,)
    norm: jnp.ndarray  # ||g_i^t||_2, (n_devices,)  (uploaded for scheduling)


def local_stats(g: jnp.ndarray) -> GradStats:
    """Compute the scalars each device uploads over the control channel."""
    mean = jnp.mean(g, axis=-1)
    var = jnp.mean((g - mean[:, None]) ** 2, axis=-1)
    norm = jnp.linalg.norm(g, axis=-1)
    return GradStats(mean=mean, var=var, norm=norm)


def global_stats(stats: GradStats, rho: jnp.ndarray, mask: jnp.ndarray):
    """Server-side global normalization stats M_g, V_g = Σ_{i∈S} ρ_i {M_i, V_i}."""
    w = rho * mask
    m_g = jnp.sum(w * stats.mean)
    v_g = jnp.sum(w * stats.var)
    return m_g, v_g


def normalize(g: jnp.ndarray, m_g: jnp.ndarray, v_g: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5: s_i = (g_i - M_g 1) / sqrt(V_g)."""
    return (g - m_g) / jnp.sqrt(eps_guard(v_g))


def denoise_scalar(
    rho: jnp.ndarray, h_abs: jnp.ndarray, mask: jnp.ndarray, tx_power: float
) -> jnp.ndarray:
    """Lemma 1, Eq. 13: a = min_{i∈S} sqrt(P) |h_i| / ρ_i (over the scheduled set)."""
    ratio = safe_div(jnp.sqrt(tx_power) * h_abs, rho)
    return jnp.min(jnp.where(mask > 0, ratio, jnp.inf))


def transmit_scalars(
    rho: jnp.ndarray, h: jnp.ndarray, a: jnp.ndarray
) -> jnp.ndarray:
    """Lemma 1, Eq. 12: b_i = ρ_i a / h_i (channel-inversion pre-equalization)."""
    return rho.astype(h.dtype) * a.astype(h.dtype) / h


def distortion_closed_form(
    v_g: jnp.ndarray,
    rho: jnp.ndarray,
    h_abs: jnp.ndarray,
    mask: jnp.ndarray,
    dim: int,
    tx_power: float,
    noise_power: float,
) -> jnp.ndarray:
    """Eq. 15: e_com = D σ_z² V_g / P · max_{i∈S} ρ_i² / |h_i|²."""
    ratio = jnp.where(mask > 0, safe_div(rho, h_abs) ** 2, 0.0)
    return dim * noise_power * v_g / tx_power * jnp.max(ratio)


def combine_given_stats(
    g: jnp.ndarray,
    rho: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    z: jnp.ndarray,
    m_g: jnp.ndarray,
    v_g: jnp.ndarray,
    a: jnp.ndarray,
    simulate_physical: bool = True,
) -> jnp.ndarray:
    """The D-elementwise tail of the Eq. 5→16 chain, given the precomputed
    global stats (M_g, V_g), denoise scalar ``a`` and noise draw ``z``.

    Factored out of :func:`aircomp_aggregate` op for op so the model-sharded
    lattice (``core.pofl.ModelShard``) can run the identical arithmetic on a
    shard-local ``(n_devices, D_local)`` block inside ``shard_map``: every
    operation here is elementwise over D (the device-axis reduction stays
    local to the block), so a D-shard of the output equals the same slice of
    the unsharded output bitwise.
    """
    if simulate_physical:
        s = normalize(g, m_g, v_g)  # (n_devices, D) symbols
        b = transmit_scalars(rho, h, a)  # (n_devices,) complex
        # an empty scheduled set (possible under sim dropout) gives a=inf and
        # rho=0, so b = 0·inf = NaN; zero unscheduled transmitters *before*
        # the mask multiply — 0·NaN would stay NaN after it
        b = jnp.where(mask > 0, b, jnp.zeros((), b.dtype))
        tx = (mask.astype(h.dtype) * b * h)[:, None] * s.astype(h.dtype)
        y_tilde = jnp.real(jnp.sum(tx, axis=0)) + z  # superposition (Eq. 7)
        return jnp.sqrt(eps_guard(v_g)) * y_tilde / a + m_g  # Eq. 8
    noise = jnp.sqrt(eps_guard(v_g)) / a * z
    return jnp.sum((mask * rho)[:, None] * g, axis=0) + noise  # Eq. 16


def aircomp_aggregate(
    g: jnp.ndarray,
    rho: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    key: jax.Array,
    tx_power: float,
    noise_power: float,
    simulate_physical: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full Eq. 5→16 signal chain. Returns (ŷ, e_com).

    Args:
      g:    (n_devices, D) stacked local gradients.
      rho:  (n_devices,) aggregation weights ρ_i (already includes 1/p_i in PO-FL).
      h:    (n_devices,) complex channel coefficients.
      mask: (n_devices,) 0/1 scheduled indicator.
      simulate_physical: if True, walk the full physical path
        (normalize → transmit scale → superpose → denoise → denormalize);
        if False, use the Lemma-1-simplified Eq. 16 (identical in law).
    """
    stats = local_stats(g)
    m_g, v_g = global_stats(stats, rho, mask)
    h_abs = jnp.abs(h)
    a = denoise_scalar(rho, h_abs, mask, tx_power)

    dim = g.shape[-1]
    # Receiver noise convention: the paper's Eq. 15 distortion follows from
    # E[|z[d]|²] = σ_z² acting on the (real) gradient estimate, so we model the
    # post-detection noise as a *real* Gaussian with variance σ_z² per entry
    # (the closed form then matches Monte Carlo exactly — see tests).
    z = jax.random.normal(key, (dim,)) * jnp.sqrt(noise_power)

    y_hat = combine_given_stats(
        g, rho, h, mask, z, m_g, v_g, a, simulate_physical=simulate_physical
    )

    e_com = distortion_closed_form(
        v_g, rho, h_abs, mask, dim, tx_power, noise_power
    )
    return y_hat, e_com


def power_check(
    rho: jnp.ndarray, h: jnp.ndarray, a: jnp.ndarray, tx_power: float
) -> jnp.ndarray:
    """|b_i|² ≤ P for all devices (Eq. 6) — holds by construction of Lemma 1."""
    b = transmit_scalars(rho, h, a)
    return jnp.abs(b) ** 2 <= tx_power * (1.0 + 1e-5)
