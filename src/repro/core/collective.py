"""The AirComp superposition as a TPU collective (DESIGN.md §2).

Over-the-air computation exploits the MAC's superposition: every device
transmits simultaneously and the receiver observes the *sum*. On a TPU mesh
the identical computational pattern is a weighted ``psum`` over the
FL-device axes plus post-sum Gaussian noise — a *noisy all-reduce*:

    ŷ = Σ_i c_i · g_i + ν·z,   c_i = mask_i · ρ_i,  ν = sqrt(V_g)/a

Two call styles are provided:

  * :func:`aircomp_allreduce` — called *inside* an existing ``shard_map``
    body; this is the building block the distributed trainer composes.
  * :func:`make_sharded_aggregator` — builds a complete ``shard_map``-wrapped
    aggregator over a mesh for stacked per-device gradients (used in tests
    to validate agreement with the pure-jnp reference in core/aircomp.py).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-compat shim: ``jax.shard_map`` (new, ``check_vma``) falls back
    to ``jax.experimental.shard_map.shard_map`` (old, ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def aircomp_allreduce(
    local_grads,
    coeff: jnp.ndarray,
    noise_amp: jnp.ndarray,
    key: jax.Array,
    axis_names: str | Sequence[str],
):
    """Noisy weighted all-reduce over ``axis_names`` (call inside shard_map).

    Args:
      local_grads: pytree of this slice's local gradients.
      coeff:       scalar c_i for this slice (0 if unscheduled).
      noise_amp:   scalar ν = sqrt(V_g)/a — receiver-noise amplitude.
      key:         PRNG key; must be *identical* across slices so every slice
                   adds the same receiver noise (the server noise is common).
    """
    leaves, treedef = jax.tree.flatten(local_grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        summed = jax.lax.psum(leaf * coeff.astype(leaf.dtype), axis_names)
        noise = noise_amp.astype(leaf.dtype) * jax.random.normal(k, leaf.shape, leaf.dtype)
        out.append(summed + noise)
    return jax.tree.unflatten(treedef, out)


def make_sharded_aggregator(mesh, axis_name: str = "data"):
    """shard_map aggregator for stacked per-device grads ``(N, D)``.

    N must equal the mesh axis size; device i's gradient lives on slice i.
    Returns ``fn(g, coeffs, noise_amp, key) -> (D,)`` with g sharded over
    the device axis — the distributed twin of ``aircomp.aircomp_aggregate``'s
    Eq. 16 path.
    """

    def body(g_local, coeffs_local, noise_amp, key):
        # g_local: (1, D) — this slice's device gradient; coeffs_local: (1,)
        y = aircomp_allreduce(
            g_local[0], coeffs_local[0], noise_amp, key, axis_name
        )
        return y[None, :]

    wrapped = _shard_map(
        body,
        mesh,
        in_specs=(P(axis_name, None), P(axis_name), P(), P()),
        out_specs=P(axis_name, None),
    )

    def agg(g, coeffs, noise_amp, key):
        out = wrapped(g, coeffs, noise_amp, key)
        return out[0]  # all slices hold the same psum result

    return agg


@partial(jax.jit, static_argnames=("axis_names",))
def _noop(x, axis_names):  # pragma: no cover - import-time sanity helper
    return x
