"""Local-update algorithms: multi-step device optimization behind one axis.

The seed round body uploads a single mini-batch gradient per device per
round. Real over-the-air FL systems upload multi-step local-update DELTAS,
and the standard remedies for non-IID client drift (FedProx, FedDyn,
SCAFFOLD) differ only in the *effective gradient* each local SGD step
follows. This module factors that into one stage:

    local_update_stage: (params, k_batch, alg_state) -> (Δ, alg_state')

Each device runs ``cfg.local_steps`` SGD steps (an inner ``lax.scan`` over
per-step mini-batch keys) on its own copy of the weights and uploads the
*average effective gradient*

    Δ_i = (1/K) Σ_k ĝ_i(w_i^k)   ==   (w^t − w_i^K) / (K · η_l)

(the equalities are exact in exact arithmetic; the accumulated form keeps
``K=1`` literally a single gradient). Δ_i feeds the unchanged scheduling →
AirComp → apply-update chain, so Lemma 2's ``Δ_i/π_i`` reweighting — and the
whole unbiasedness analysis — transfers verbatim from gradients to deltas
(pinned by tests/test_local_update.py's hypothesis suite).

The algorithm axis mirrors PR 5's ``policy_id`` design exactly:

  * ``ALGORITHMS`` is an APPEND-ONLY tuple — ``ALGORITHM_IDS[name]`` is the
    int32 ``lax.switch`` branch index, so ids are stable forever (same
    contract as ``scheduling.POLICY_IDS``; see ROADMAP "builder notes").
  * Static dispatch (``algorithm_id=None``): ``cfg.local_algorithm`` selects
    the branch as a Python string; ``fedavg`` (or ``fedprox``, whose
    proximal term is identically zero on the first local step) at
    ``local_steps=1`` short-circuits to :func:`local_gradient_stage` — the
    EXACT legacy one-gradient ops, so every seed-pinned trajectory is
    bitwise unchanged.
  * Traced dispatch (``algorithm_id`` an int32 array): one ``lax.switch``
    branch table over the effective-gradient rules, so a multi-algorithm
    lattice compiles ONCE (``sim.lattice`` vmaps the id per cell).

Per-device algorithm state rides the engine's donated scan carry as
:class:`AlgState` — ``h`` is FedDyn's drift h_i, ``c`` is SCAFFOLD's control
variate c_i, and ``None`` leaves flatten to EMPTY pytree subtrees (the PR-6
``diag=None`` trick), so stateless algorithms leave the carry structure —
and therefore the compiled legacy program — untouched.

The effective-gradient rules (w0 = w^t broadcast per device):

    fedavg    ĝ = g(w)
    fedprox   ĝ = g(w) + μ (w − w0)                   [μ = cfg.fedprox_mu]
    feddyn    ĝ = g(w) − h_i + α_d (w − w0);  h_i' = h_i − α_d (w_i^K − w0)
    scaffold  ĝ = g(w) − c_i + c̄;            c_i' = c_i − c̄ + Δ_i
                                              (Option II, uniform c̄ = mean c_i)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

# APPEND-ONLY (the lax.switch branch table below and every persisted
# algorithm id depend on these positions — add new algorithms at the END)
ALGORITHMS = ("fedavg", "fedprox", "feddyn", "scaffold")
ALGORITHM_IDS = {name: i for i, name in enumerate(ALGORITHMS)}
FEDAVG_ID = ALGORITHM_IDS["fedavg"]
FEDPROX_ID = ALGORITHM_IDS["fedprox"]
FEDDYN_ID = ALGORITHM_IDS["feddyn"]
SCAFFOLD_ID = ALGORITHM_IDS["scaffold"]

# algorithms whose per-device state is empty (AlgState leaves all None →
# the scan carry keeps the legacy pytree structure)
STATELESS = ("fedavg", "fedprox")


def algorithm_id(algorithm: str) -> int:
    """The stable ``lax.switch`` branch index of a local-update algorithm."""
    if algorithm not in ALGORITHM_IDS:
        raise ValueError(
            f"unknown local_algorithm {algorithm!r}; choose from {ALGORITHMS}"
        )
    return ALGORITHM_IDS[algorithm]


class AlgState(NamedTuple):
    """Per-device local-algorithm state carried across rounds.

    ``None`` fields flatten to EMPTY pytree subtrees (zero leaves, zero
    ops), so a stateless algorithm's :class:`~repro.sim.engine.SimState`
    is structurally identical to the pre-algorithm-axis carry.
    """

    h: Any = None  # FedDyn per-device drift h_i, (N, D) or None
    c: Any = None  # SCAFFOLD per-device control variate c_i, (N, D) or None


def init_state(
    local_algorithm: str, n_devices: int, dim: int, full: bool = False
) -> AlgState | None:
    """Zero-initialized algorithm state for one cell.

    ``full=True`` builds EVERY state field regardless of the algorithm name —
    the traced ``lax.switch`` dispatch evaluates all branches, so a fused
    multi-algorithm lattice must carry the union (fedavg/fedprox branches
    simply pass h/c through unchanged). ``full=False`` (static dispatch)
    returns ``None`` for stateless algorithms so the carry structure — and
    every pinned trajectory — stays bit-identical to the legacy engine.
    """
    zeros = lambda: jnp.zeros((n_devices, dim), jnp.float32)  # noqa: E731
    if full:
        return AlgState(h=zeros(), c=zeros())
    algorithm_id(local_algorithm)  # hard error on unknown names
    if local_algorithm == "feddyn":
        return AlgState(h=zeros(), c=None)
    if local_algorithm == "scaffold":
        return AlgState(h=None, c=zeros())
    return None


def draw_minibatch(data, cfg, k_batch: jax.Array):
    """Per-device mini-batch draw → (feats, labels), each leading (N, B).

    Equal shards keep the seed's exact ``randint`` draw (bit-identical
    trajectories); heterogeneous shards draw uniformly over each device's
    valid prefix so padded rows are never touched.
    """
    n = data.n_devices
    m = data.samples_per_device
    if data.n_samples is None:
        idx = jax.random.randint(k_batch, (n, cfg.batch_size), 0, m)
    else:
        # n_samples is static partition metadata — reject empty devices at
        # trace time (idx = min(·, -1) would wrap to the last PADDED row)
        if (np.asarray(data.n_samples) < 1).any():
            raise ValueError(
                "every device needs n_samples >= 1; drop empty devices from "
                "the partition instead"
            )
        ns = jnp.asarray(data.n_samples, jnp.int32)
        u = jax.random.uniform(k_batch, (n, cfg.batch_size))
        idx = jnp.minimum(
            (u * ns[:, None].astype(u.dtype)).astype(jnp.int32), ns[:, None] - 1
        )
    feats = jnp.take_along_axis(
        data.features,
        idx.reshape((n, cfg.batch_size) + (1,) * (data.features.ndim - 2)),
        axis=1,
    )
    labels = jnp.take_along_axis(data.labels, idx, axis=1)
    return feats, labels


def _device_gradients(loss_fn, params, feats, labels):
    """vmap(jax.grad) over the device axis → stacked flat gradients (N, D)."""

    def one(fx, fy):
        g = jax.grad(loss_fn)(params, fx, fy)
        flat, _ = ravel_pytree(g)
        return flat

    return jax.vmap(one)(feats, labels)


def _device_gradients_at(loss_fn, unravel, w_flat, feats, labels):
    """Per-device gradients at per-device weights → (N, D). Unlike
    :func:`_device_gradients` the weights have diverged (local steps > 1),
    so the vmap carries a flat weight row per device."""

    def one(wf, fx, fy):
        g = jax.grad(loss_fn)(unravel(wf), fx, fy)
        flat, _ = ravel_pytree(g)
        return flat

    return jax.vmap(one)(w_flat, feats, labels)


def local_gradient_stage(
    loss_fn: Callable,
    data,
    cfg,
    params,
    k_batch: jax.Array,
) -> jnp.ndarray:
    """Step 2 of Algorithm 1: one mini-batch draw + vmapped grads → (N, D).

    The legacy one-gradient round body — kept verbatim as the ``fedavg`` /
    ``local_steps=1`` short-circuit of :func:`local_update_stage`, so every
    seed-pinned trajectory stays bitwise unchanged.
    """
    feats, labels = draw_minibatch(data, cfg, k_batch)
    return _device_gradients(loss_fn, params, feats, labels)


def _effective_gradient_branches(mu, a_dyn, h, c, cbar):
    """The APPEND-ONLY ``lax.switch`` branch table, ``ALGORITHMS`` order.

    Every branch maps ``(g, drift)`` — the stacked mini-batch gradients and
    ``w − w0`` per device — to the effective gradient its local SGD step
    follows. New algorithms append; existing indices never move (same
    contract as ``scheduling.scheduling_probs_by_id``).
    """
    return [
        lambda g, drift: g,                      # fedavg
        lambda g, drift: g + mu * drift,         # fedprox (proximal pull)
        lambda g, drift: g - h + a_dyn * drift,  # feddyn (dynamic regularizer)
        lambda g, drift: g - c + cbar,           # scaffold (control variates)
    ]


def local_update_stage(
    loss_fn: Callable,
    data,
    cfg,
    params,
    k_batch: jax.Array,
    t,
    alg_state: AlgState | None = None,
    algorithm_id: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, AlgState | None]:
    """Steps 2–2b: ``cfg.local_steps`` local SGD steps per device → (Δ, state').

    Returns the (N, D) per-device average effective gradient Δ_i — a drop-in
    replacement for the legacy single gradient in the scheduling/AirComp
    chain — plus the updated :class:`AlgState`.

    Dispatch contract (mirrors ``core.pofl.scheduling_stage``):

      * ``algorithm_id=None`` → static string dispatch on
        ``cfg.local_algorithm``. ``fedavg``/``fedprox`` at ``local_steps=1``
        short-circuit to the EXACT legacy :func:`local_gradient_stage` ops
        (the proximal term is identically zero on the first local step) —
        the bit-identity pin every golden trajectory rides on.
      * ``algorithm_id`` a traced int32 (``ALGORITHM_IDS`` order) → the
        ``lax.switch`` branch table; the fused lattice vmaps it per cell,
        and ``alg_state`` must then carry EVERY field
        (``init_state(..., full=True)``) because all branches are traced.

    The per-step mini-batch keys split off ``k_batch`` — except at
    ``local_steps=1``, where the single step consumes ``k_batch`` itself so
    the draw (and the whole round) matches the legacy program bit for bit.
    """
    K = int(cfg.local_steps)
    if K < 1:
        raise ValueError(f"local_steps must be >= 1, got {K}")
    if algorithm_id is None:
        name = cfg.local_algorithm
        if name not in ALGORITHM_IDS:
            raise ValueError(
                f"unknown local_algorithm {name!r}; choose from {ALGORITHMS}"
            )
        if K == 1 and name in STATELESS:
            # op-for-op the legacy one-gradient round (Δ_i = g_i exactly)
            return local_gradient_stage(loss_fn, data, cfg, params, k_batch), alg_state
        if name not in STATELESS and (
            alg_state is None or getattr(alg_state, "h" if name == "feddyn" else "c") is None
        ):
            raise ValueError(
                f"{name} needs per-device AlgState in the scan carry; run it "
                "through repro.sim.SimEngine (init_state builds the state)"
            )
    else:
        name = None
        if alg_state is None or alg_state.h is None or alg_state.c is None:
            raise ValueError(
                "traced algorithm dispatch evaluates every branch, so "
                "alg_state must carry all fields — init_state(..., full=True)"
            )

    flat0, unravel = ravel_pytree(params)
    n = data.n_devices
    w0 = jnp.broadcast_to(flat0, (n, flat0.size))
    lr_l = cfg.lr(t) if cfg.local_lr is None else jnp.asarray(cfg.local_lr, jnp.float32)
    mu = jnp.asarray(cfg.fedprox_mu, jnp.float32)
    a_dyn = jnp.asarray(cfg.feddyn_alpha, jnp.float32)

    h = None if alg_state is None else alg_state.h
    c = None if alg_state is None else alg_state.c
    cbar = None if c is None else jnp.mean(c, axis=0)

    if algorithm_id is None:
        eff = _effective_gradient_branches(mu, a_dyn, h, c, cbar)[ALGORITHM_IDS[name]]
    else:
        branches = _effective_gradient_branches(mu, a_dyn, h, c, cbar)
        alg_id = algorithm_id

        def eff(g, drift):
            return jax.lax.switch(alg_id, branches, g, drift)

    # K=1 consumes k_batch itself (the legacy draw); K>1 splits per step
    step_keys = k_batch[None] if K == 1 else jax.random.split(k_batch, K)

    def step(carry, k_step):
        w, acc = carry
        feats, labels = draw_minibatch(data, cfg, k_step)
        g = _device_gradients_at(loss_fn, unravel, w, feats, labels)
        ghat = eff(g, w - w0)
        return (w - lr_l * ghat, acc + ghat), None

    (w_k, acc), _ = jax.lax.scan(step, (w0, jnp.zeros_like(w0)), step_keys)
    delta = acc / K                    # (w0 − w_K) / (K η_l) in exact arithmetic
    drift_k = w_k - w0                 # per-device end-of-round drift

    if algorithm_id is None:
        if name == "feddyn":
            new_state = AlgState(h=h - a_dyn * drift_k, c=None)
        elif name == "scaffold":
            new_state = AlgState(h=None, c=c - cbar + delta)
        else:
            new_state = alg_state
    else:
        # state updates switch on the same branch index (ALGORITHMS order,
        # append-only): stateless branches pass (h, c) through unchanged
        new_h, new_c = jax.lax.switch(
            algorithm_id,
            [
                lambda: (h, c),                          # fedavg
                lambda: (h, c),                          # fedprox
                lambda: (h - a_dyn * drift_k, c),        # feddyn
                lambda: (h, c - cbar + delta),           # scaffold
            ],
        )
        new_state = AlgState(h=new_h, c=new_c)
    return delta, new_state
