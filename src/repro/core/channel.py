"""Wireless channel model for over-the-air FL (paper Sec. V-A).

Rayleigh block-fading channels with free-space path loss:

    h_i^t = sqrt(g_i) * lambda_i^t,     lambda_i^t ~ CN(0, 1)
    g_i   = G0 * (c / (4 pi f0 d_i))^PL

The channel is *simulated* (seeded PRNG) — on a TPU mesh the links are
reliable, so fading/noise are injected explicitly (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

C_LIGHT = 3.0e8


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Physical-layer constants (defaults = paper Sec. V-A)."""

    n_devices: int = 30
    d_min: float = 10.0          # min device-server distance [m]
    d_max: float = 50.0          # max device-server distance [m]
    antenna_gain: float = 4.11   # G0
    carrier_freq: float = 915e6  # f0 [Hz]
    path_loss_exp: float = 3.76  # PL
    tx_power: float = 1.0        # P [W]
    noise_power: float = 1e-11   # sigma_z^2 [W]


def path_loss(cfg: ChannelConfig, distances: jnp.ndarray) -> jnp.ndarray:
    """Free-space path loss g_i for device distances [m]."""
    wavelength_term = C_LIGHT / (4.0 * jnp.pi * cfg.carrier_freq * distances)
    return cfg.antenna_gain * wavelength_term ** cfg.path_loss_exp


def device_distances(cfg: ChannelConfig, key: jax.Array) -> jnp.ndarray:
    """Uniformly distributed device distances in [d_min, d_max]."""
    return jax.random.uniform(
        key, (cfg.n_devices,), minval=cfg.d_min, maxval=cfg.d_max
    )


@partial(jax.jit, static_argnums=0)
def sample_channels(cfg: ChannelConfig, gains: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """Sample complex channel coefficients h_i^t (Rayleigh block fading).

    Returns complex64 array of shape (n_devices,).
    """
    k_re, k_im = jax.random.split(key)
    lam = (
        jax.random.normal(k_re, gains.shape) + 1j * jax.random.normal(k_im, gains.shape)
    ) / jnp.sqrt(2.0)
    return jnp.sqrt(gains).astype(jnp.complex64) * lam.astype(jnp.complex64)


@dataclasses.dataclass(frozen=True)
class ChannelState:
    """Static per-run channel state (distances/gains are drawn once)."""

    cfg: ChannelConfig
    gains: jnp.ndarray  # (n_devices,)

    @staticmethod
    def create(cfg: ChannelConfig, key: jax.Array) -> "ChannelState":
        dists = device_distances(cfg, key)
        return ChannelState(cfg=cfg, gains=path_loss(cfg, dists))

    def sample(self, key: jax.Array) -> jnp.ndarray:
        """Draw this round's fading realization h^t (complex, (n_devices,))."""
        return sample_channels(self.cfg, self.gains, key)
