"""PO-FL — Algorithm 1: the faithful over-the-air FL simulator.

This is the paper's training loop at paper scale (N≈30 devices, vmap over
devices). Every step of Algorithm 1 is implemented:

  1. broadcast w^t                      (implicit — shared params)
  2. local mini-batch gradients g_i^t   (vmap of jax.grad over devices)
  3. upload scalar stats M_i, V_i, ||g_i||
  4. server computes p_i^t (scheduling.py), samples S^t, broadcasts stats
  5. devices normalize + transmit concurrently; server denoises (aircomp.py)
  6. w^{t+1} = w^t − η^t ŷ^t

The round body lives in :func:`round_algorithm` so that both the legacy
per-round jit (:func:`make_round_step`) and the scanned simulation engine
(``repro.sim.engine``) execute the *same* traced computation. ``run_pofl``
is a thin compatibility wrapper over the engine (identical trajectories for
identical seeds — pinned by tests/test_sim.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import aircomp, scheduling
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.metrics import RoundMetrics


@dataclasses.dataclass(frozen=True)
class POFLConfig:
    """Hyper-parameters for the PO-FL simulator (defaults = paper Sec. V-A)."""

    n_devices: int = 30
    n_scheduled: int = 10
    alpha: float = 0.1
    policy: str = "pofl"
    sampler: str = "without_replacement"  # or "bernoulli" (PO-FL-B variant)
    tx_power: float = 1.0
    noise_power: float = 1e-11
    batch_size: int = 10
    lr0: float = 0.1
    lr_decay: float = 0.95
    lr_min: float = 1e-5
    simulate_physical: bool = False  # full Eq.5→8 path vs Eq.16 (same in law)
    seed: int = 0

    def lr(self, t: jnp.ndarray) -> jnp.ndarray:
        """Paper Sec. V-A: η^t = max(η0 · 0.95^t, 1e-5)."""
        return jnp.maximum(self.lr0 * self.lr_decay**t, self.lr_min)


class DeviceData(NamedTuple):
    """Stacked per-device datasets (equal shard sizes, as in the paper)."""

    features: jnp.ndarray  # (N, m, ...)
    labels: jnp.ndarray    # (N, m)

    @property
    def n_devices(self) -> int:
        return self.features.shape[0]

    @property
    def samples_per_device(self) -> int:
        return self.features.shape[1]


class History(NamedTuple):
    loss: list
    e_com: list
    e_var: list
    test_acc: list
    test_round: list


def _device_gradients(loss_fn, params, feats, labels):
    """vmap(jax.grad) over the device axis → stacked flat gradients (N, D)."""

    def one(fx, fy):
        g = jax.grad(loss_fn)(params, fx, fy)
        flat, _ = ravel_pytree(g)
        return flat

    return jax.vmap(one)(feats, labels)


def round_algorithm(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    data: DeviceData,
    cfg: POFLConfig,
    params,
    h: jnp.ndarray,
    k_batch: jax.Array,
    k_sched: jax.Array,
    k_noise: jax.Array,
    t: jnp.ndarray,
    noise_power: jnp.ndarray | float | None = None,
    alpha: jnp.ndarray | float | None = None,
    avail: jnp.ndarray | None = None,
) -> tuple[Any, RoundMetrics]:
    """Steps 2–6 of Algorithm 1 for one round, given this round's channel ``h``.

    ``noise_power`` / ``alpha`` default to the (static) config values but may
    be traced arrays — the simulation lattice vmaps over them. Everything
    structural (policy, sampler, |S|, batch size) stays static.

    ``avail`` is an optional (N,) 0/1 availability mask (sim dropout
    scenarios): unavailable devices get zero scheduling probability this
    round. ``None`` (the default, and the only value the legacy path ever
    passes) skips the masking entirely, keeping the static-scenario
    trajectory bit-identical to the seed implementation.
    """
    noise_power = cfg.noise_power if noise_power is None else noise_power
    alpha = cfg.alpha if alpha is None else alpha

    n = data.n_devices
    m = data.samples_per_device
    data_frac = jnp.full((n,), 1.0 / n)  # equal shards: m_i/M = 1/N

    noise_free = cfg.policy == "noisefree"
    agg_noise_power = 0.0 if noise_free else noise_power

    # -- step 2: local mini-batch gradients ---------------------------
    idx = jax.random.randint(k_batch, (n, cfg.batch_size), 0, m)
    feats = jnp.take_along_axis(
        data.features,
        idx.reshape((n, cfg.batch_size) + (1,) * (data.features.ndim - 2)),
        axis=1,
    )
    labels = jnp.take_along_axis(data.labels, idx, axis=1)
    g = _device_gradients(loss_fn, params, feats, labels)  # (N, D)
    dim = g.shape[-1]

    # -- step 3: uploaded scalar statistics ---------------------------
    stats = aircomp.local_stats(g)

    # -- step 4: scheduling -------------------------------------------
    h_abs = jnp.abs(h)
    probs = scheduling.scheduling_probs(
        cfg.policy, stats.norm, stats.var, h_abs, data_frac, dim,
        alpha, cfg.tx_power, noise_power,
    )
    if avail is not None:
        masked = probs * avail
        probs = masked / jnp.maximum(jnp.sum(masked), 1e-30)
    if cfg.policy == "deterministic":
        sched = scheduling.sample_without_replacement(k_sched, probs, cfg.n_scheduled)
        rho = scheduling.deterministic_weights(sched, data_frac)
        mask = sched.mask
    elif cfg.sampler == "bernoulli":
        mask, pi = scheduling.sample_bernoulli(k_sched, probs, cfg.n_scheduled)
        rho = scheduling.bernoulli_weights(pi, data_frac)
    else:
        sched = scheduling.sample_without_replacement(k_sched, probs, cfg.n_scheduled)
        rho = scheduling.aggregation_weights(sched, probs, data_frac, cfg.n_scheduled)
        mask = sched.mask

    # -- steps 5-6: AirComp aggregation + model update ----------------
    y_hat, e_com = aircomp.aircomp_aggregate(
        g, rho, h, mask, k_noise, cfg.tx_power, agg_noise_power,
        simulate_physical=cfg.simulate_physical,
    )
    e_var = scheduling.global_update_variance(g, rho, mask, data_frac, cfg.n_scheduled)

    flat_params, unravel_p = ravel_pytree(params)
    new_params = unravel_p(flat_params - cfg.lr(t) * y_hat)

    a = aircomp.denoise_scalar(rho, h_abs, mask, cfg.tx_power)
    metrics = RoundMetrics(
        loss=jnp.zeros(()),  # filled by caller's eval if desired
        e_com=e_com,
        e_var=e_var,
        grad_norm=jnp.linalg.norm(y_hat),
        n_scheduled=jnp.sum(mask),
        a_scalar=a,
    )
    return new_params, metrics


def make_round_step(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    data: DeviceData,
    channel: ChannelState,
    cfg: POFLConfig,
):
    """Build the jitted single-round step implementing Algorithm 1."""

    def round_step(params, key, t):
        k_batch, k_chan, k_sched, k_noise = jax.random.split(key, 4)
        h = channel.sample(k_chan)
        return round_algorithm(
            loss_fn, data, cfg, params, h, k_batch, k_sched, k_noise, t
        )

    return jax.jit(round_step)


def run_pofl(
    loss_fn,
    params0,
    data: DeviceData,
    cfg: POFLConfig,
    n_rounds: int,
    eval_fn: Callable[[Any], tuple[float, float]] | None = None,
    eval_every: int = 5,
    channel_cfg: ChannelConfig | None = None,
) -> tuple[Any, History]:
    """Run Algorithm 1 for ``n_rounds`` and return (params, history).

    Compatibility wrapper over ``repro.sim.engine.SimEngine``: the T-round
    loop is a ``lax.scan`` chunked at the evaluation boundaries, so metrics
    only sync to host once per eval interval instead of once per round. The
    trajectory is identical (same PRNG key discipline, same round body) to
    the historical per-round Python loop — see tests/test_sim.py.
    """
    from repro.sim.engine import SimEngine  # late import: sim builds on core

    engine = SimEngine(
        loss_fn=loss_fn, data=data, cfg=cfg, channel_cfg=channel_cfg
    )
    return engine.run_with_history(
        params0, n_rounds, eval_fn=eval_fn, eval_every=eval_every
    )
