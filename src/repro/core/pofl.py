"""PO-FL — Algorithm 1: the faithful over-the-air FL simulator.

This is the paper's training loop at paper scale (N≈30 devices, vmap over
devices). Every step of Algorithm 1 is implemented:

  1. broadcast w^t                      (implicit — shared params)
  2. local mini-batch gradients g_i^t   (vmap of jax.grad over devices)
  3. upload scalar stats M_i, V_i, ||g_i||
  4. server computes p_i^t (scheduling.py), samples S^t, broadcasts stats
  5. devices normalize + transmit concurrently; server denoises (aircomp.py)
  6. w^{t+1} = w^t − η^t ŷ^t

The round body is an explicit **pipeline of composable stages**

    local_update_stage → scheduling_stage → aggregation_stage → apply_update_stage

(``core.local_update``'s :func:`~repro.core.local_update.local_update_stage`
generalizes the historical single-gradient ``local_gradient_stage`` —
re-exported here unchanged — to ``cfg.local_steps`` local SGD steps under a
``cfg.local_algorithm`` ∈ {fedavg, fedprox, feddyn, scaffold} branch table;
the default ``fedavg``/``local_steps=1`` traces the EXACT legacy program)
composed by :func:`round_algorithm` so that the legacy per-round jit
(:func:`make_round_step`), the scanned simulation engine
(``repro.sim.engine``) and the lattice all execute the *same* traced
computation. The transmit/aggregate stage is parameterized by an
:class:`AggregationBackend`:

  * ``jnp``           — the exact reference path (Eq. 16 / full Eq. 5→8,
    per ``cfg.simulate_physical``); the default, bit-identical to the seed.
  * ``pallas_fused``  — the one-HBM-pass fused Eq. 5→8 kernel
    (``kernels/aircomp``): the Pallas TPU kernel on TPU, its pure-jnp oracle
    on CPU, interpret mode via ``REPRO_PALLAS_INTERPRET=1`` (parity path).
    Semantics are the *physical* chain (algebraically equal to
    ``simulate_physical=True``; differs from Eq. 16 by ``(1−Σρ)·M_g``).

Data may be heterogeneous: :class:`DeviceData` optionally carries per-device
sample counts ``n_samples`` (shards padded to a common length), and the
m_i/M weights of Eq. 34/35/37 follow the true fractions. ``run_pofl`` is a
thin compatibility wrapper over the engine (identical trajectories for
identical seeds — pinned by tests/test_sim.py) with engine/jit caching
across calls keyed by (task, cfg-minus-seed, backend).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.flatten_util import ravel_pytree
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import aircomp, scheduling
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.local_update import (  # noqa: F401  (re-exported API)
    AlgState,
    local_gradient_stage,
    local_update_stage,
)
from repro.core.metrics import RoundHealth, RoundMetrics, diagnostics_taps
from repro.core.numerics import safe_div


class AggregationBackend(str, enum.Enum):
    """How the transmit/aggregate stage realizes the Eq. 5→8 signal chain."""

    JNP = "jnp"                    # exact reference (Eq. 16 or full Eq. 5→8)
    PALLAS_FUSED = "pallas_fused"  # fused one-pass kernel (physical semantics)


BACKENDS = tuple(b.value for b in AggregationBackend)


@dataclasses.dataclass(frozen=True)
class ModelShard:
    """Model-dimension sharding context for the round pipeline.

    Built by ``repro.sim.engine.SimEngine`` when its mesh carries a
    ``"model"`` axis of size > 1 (a 2-D ``("cells", "model")`` mesh from
    ``repro.sim.lattice.make_cell_model_mesh``). When threaded into
    :func:`round_algorithm` it reroutes the D-elementwise hot path through
    ``shard_map`` over the model axis:

      * the flat (N, D) gradient block is zero-padded to a multiple of
        ``|model| · tile_d`` and constrained to ``P(None, "model")`` — each
        device holds only its own ``D/|model|`` columns;
      * the Eq. 5 statistics M_i, V_i, ||g_i|| become small ``psum``\\ s of
        shard-local partial sums over the model axis (padding columns are
        masked out, so values match the unsharded stats up to reduction
        order);
      * the aggregation stage runs shard-locally on each
        ``(n_devices, D_local)`` block — the fused Pallas kernel's grid is
        aligned to the shard (``kernels/aircomp`` clamps its tile to the
        block), the ``jnp`` reference uses the identical factored-out
        :func:`repro.core.aircomp.combine_given_stats` — with no collective
        at all: the device-axis reduction is elementwise over D;
      * the updated params carry is constrained back to its model-sharded
        placement (``repro.launch.sharding.param_spec``) so the scan carry
        keeps a stable sharding across rounds.

    Everything outside that path (scheduling, channel, PRNG discipline,
    e_com's closed form over the TRUE dim) is untouched, and ``None`` — the
    default everywhere — leaves the traced program bit-identical to the
    unsharded engine.
    """

    mesh: Any          # jax.sharding.Mesh with a "model" axis of size > 1
    axis: str = "model"

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def padded_dim(self, dim: int) -> int:
        """D rounded up so every model shard holds a whole number of default
        kernel tiles (the fused kernel then launches a snug, pad-free grid
        on its local block)."""
        from repro.kernels.aircomp import DEFAULT_TILE_D  # late: kernels↔core

        unit = self.n_shards * DEFAULT_TILE_D
        return -(-dim // unit) * unit

    def pad_features(self, g: jnp.ndarray, dim: int) -> jnp.ndarray:
        """Zero-pad the trailing (flat-D) axis to :meth:`padded_dim` and
        constrain it to ``P(None, "model")`` placement."""
        d_pad = self.padded_dim(dim)
        if d_pad != dim:
            g = jnp.pad(g, ((0, 0), (0, d_pad - dim)))
        return jax.lax.with_sharding_constraint(
            g, NamedSharding(self.mesh, P(None, self.axis))
        )

    def leaf_sharding(self, shape) -> NamedSharding:
        """The params-leaf placement rule (reuses the dormant FSDP machinery:
        last dim divisible by |model| → "model"; tiny leaves replicated)."""
        from repro.launch.sharding import param_spec  # late: launch↔core

        return NamedSharding(self.mesh, param_spec(tuple(shape), self.mesh))


def _model_sharded_local_stats(
    ms: ModelShard, g_pad: jnp.ndarray, dim: int
) -> aircomp.GradStats:
    """Step-3 statistics over a model-sharded padded gradient block.

    Each shard reduces its own columns; only the three (N,)-sized partial
    sums cross the model axis (the "small psums" of the 2-D lattice). The
    zero-padding columns are masked out of every sum — when D divides the
    shard count the mask is all-ones and the arithmetic is a pure
    sum-then-divide, matching :func:`aircomp.local_stats` up to the
    documented cross-program reduction-order wobble.
    """
    ax = ms.axis

    def stats_block(gb):
        d_local = gb.shape[-1]
        col0 = jax.lax.axis_index(ax) * d_local
        valid = ((col0 + jnp.arange(d_local)) < dim).astype(gb.dtype)
        gv = gb * valid
        mean = jax.lax.psum(jnp.sum(gv, axis=-1), ax) / dim
        dev = (gb - mean[:, None]) * valid
        var = jax.lax.psum(jnp.sum(dev * dev, axis=-1), ax) / dim
        norm = jnp.sqrt(jax.lax.psum(jnp.sum(gv * gv, axis=-1), ax))
        return mean, var, norm

    mean, var, norm = shard_map(
        stats_block, mesh=ms.mesh,
        in_specs=(P(None, ax),), out_specs=(P(), P(), P()),
        check_rep=False,
    )(g_pad)
    return aircomp.GradStats(mean=mean, var=var, norm=norm)


def _model_sharded_combine(
    cfg: "POFLConfig",
    ms: ModelShard,
    g_pad: jnp.ndarray,
    rho: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    z_pad: jnp.ndarray,
    m_g: jnp.ndarray,
    v_g: jnp.ndarray,
    a: jnp.ndarray,
    use_pallas: str | bool,
) -> jnp.ndarray:
    """Shard-local Eq. 5→8 combine: every input except the D-sharded
    gradient/noise blocks is replicated, the output is the D-sharded ŷ, and
    no collective runs inside — the device-axis reduction is elementwise
    over D. The fused kernel launches per shard on its local
    ``(n_devices, D_local)`` block (its grid aligned to the shard); the jnp
    backend runs the identical factored-out reference arithmetic."""
    backend = AggregationBackend(cfg.backend)
    if backend is AggregationBackend.JNP:

        def agg_block(gb, zb, rho_, h_, mask_, m_g_, v_g_, a_):
            return aircomp.combine_given_stats(
                gb, rho_, h_, mask_, zb, m_g_, v_g_, a_,
                simulate_physical=cfg.simulate_physical,
            )

    else:
        from repro.kernels.aircomp import aircomp_aggregate_fused  # late

        def agg_block(gb, zb, rho_, h_, mask_, m_g_, v_g_, a_):
            coeff = mask_ * rho_  # b_i h_i = ρ_i a exactly (Lemma 1)
            return aircomp_aggregate_fused(
                gb, coeff, m_g_, v_g_, a_, zb, use_pallas=use_pallas
            )

    ax = ms.axis
    return shard_map(
        agg_block, mesh=ms.mesh,
        in_specs=(
            P(None, ax), P(ax), P(None), P(None), P(None), P(), P(), P(),
        ),
        out_specs=P(ax), check_rep=False,
    )(g_pad, z_pad, rho, h, mask, m_g, v_g, a)


@dataclasses.dataclass(frozen=True)
class POFLConfig:
    """Hyper-parameters for the PO-FL simulator (defaults = paper Sec. V-A)."""

    n_devices: int = 30
    n_scheduled: int = 10
    alpha: float = 0.1
    policy: str = "pofl"
    # "without_replacement" (the paper's sequential Eq. 36 scan), "topk"
    # (Gumbel top-k draw — same law, different PRNG stream, no S-step scan),
    # or "bernoulli" (PO-FL-B Horvitz–Thompson variant)
    sampler: str = "without_replacement"
    tx_power: float = 1.0
    noise_power: float = 1e-11
    batch_size: int = 10
    lr0: float = 0.1
    lr_decay: float = 0.95
    lr_min: float = 1e-5
    simulate_physical: bool = False  # full Eq.5→8 path vs Eq.16 (same in law)
    backend: str = "jnp"  # AggregationBackend of the aggregation stage
    # -- local-update algorithm axis (core.local_update) ----------------
    # The defaults are legacy-equivalent: fedavg at one local step traces
    # the EXACT historical one-gradient round (bit-identical trajectories).
    local_algorithm: str = "fedavg"  # ALGORITHMS name (or the lattice's sentinel)
    local_steps: int = 1             # K local SGD steps per device per round
    local_lr: float | None = None    # local step size η_l; None → cfg.lr(t)
    fedprox_mu: float = 0.0          # FedProx proximal coefficient μ
    feddyn_alpha: float = 0.1        # FedDyn dynamic-regularizer coefficient
    # -- non-finite quarantine (sim.resilience) -------------------------
    # "propagate" (default): NaN/Inf aggregates flow through untouched — the
    # seed's exact program, zero new ops. "skip": a per-round finite-ness
    # guard quarantines any round whose aggregate ŷ^t contains a non-finite
    # entry (params and AlgState hold their previous values via lax.cond)
    # and counts it on the RoundMetrics.health subtree.
    on_nonfinite: str = "propagate"
    seed: int = 0

    def lr(self, t: jnp.ndarray) -> jnp.ndarray:
        """Paper Sec. V-A: η^t = max(η0 · 0.95^t, 1e-5)."""
        return jnp.maximum(self.lr0 * self.lr_decay**t, self.lr_min)


class DeviceData(NamedTuple):
    """Stacked per-device datasets.

    Equal shards (the paper's setting): ``features`` is ``(N, m, ...)`` and
    ``n_samples`` is None. Heterogeneous shards (e.g. Dirichlet-sized
    partitions): every shard is padded to a common ``m_max`` and
    ``n_samples[i] ≤ m_max`` marks device i's valid prefix — padded rows are
    never sampled, and the m_i/M fractions in the scheduling/weight math
    follow the true counts. ``features`` may be flat ``(N, m, d)`` vectors or
    image-shaped ``(N, m, H, W, C)`` batches (the model tasks' CNN case) —
    every stage treats the trailing dims opaquely. Eval-side padded test
    sets follow the same valid-prefix contract via
    ``repro.sim.tasks.TaskEval`` / ``models.small.make_eval_fn(n_valid=...)``.
    """

    features: jnp.ndarray  # (N, m_max, ...)
    labels: jnp.ndarray    # (N, m_max)
    n_samples: Any = None  # (N,) int valid-prefix lengths, or None (equal)

    @property
    def n_devices(self) -> int:
        return self.features.shape[0]

    @property
    def samples_per_device(self) -> int:
        """Padded (maximum) shard length m_max."""
        return self.features.shape[1]

    @property
    def data_frac(self) -> jnp.ndarray:
        """m_i / M — uniform for equal shards, true fractions otherwise."""
        n = self.features.shape[0]
        if self.n_samples is None:
            return jnp.full((n,), 1.0 / n)
        ns = jnp.asarray(self.n_samples, jnp.float32)
        return ns / jnp.sum(ns)


class History(NamedTuple):
    """Host-side metric record of the ``run_pofl`` driver.

    ``loss``/``test_acc`` come from the caller's ``eval_fn`` — any Python
    ``params -> (loss, acc)`` callable, including a model task's
    ``repro.sim.tasks.TaskEval`` (whose pad-masked eval counts only the true
    test rows of a padded set). The richer on-device record schema — the
    per-round ``RoundRecord`` with its optional ``diag``/``eval`` subtrees —
    lives in ``repro.sim.engine``; this NamedTuple is the stable legacy
    surface and its fields are append-only.
    """

    loss: list
    e_com: list
    e_var: list
    test_acc: list
    test_round: list


# --------------------------------------------------------------------------
# the round pipeline stages
# --------------------------------------------------------------------------
# Step 2 — the local stage — lives in ``core.local_update``:
# ``local_gradient_stage`` (the legacy single gradient, re-exported above)
# and ``local_update_stage`` (multi-step deltas under the algorithm axis).


def scheduling_stage(
    cfg: POFLConfig,
    stats: aircomp.GradStats,
    h_abs: jnp.ndarray,
    data_frac: jnp.ndarray,
    dim: int,
    alpha,
    noise_power,
    k_sched: jax.Array,
    avail: jnp.ndarray | None = None,
    policy_id: jnp.ndarray | None = None,
    return_probs: bool = False,
) -> tuple[jnp.ndarray, ...]:
    """Step 4: p_i^t (Eq. 34/Remark 2) → draw S^t → weights ρ (Eq. 37/HT).

    Returns ``(rho, mask)`` — per-device aggregation weights and the 0/1
    scheduled indicator — or ``(rho, mask, probs)`` when ``return_probs``
    (the obs diagnostics tap needs the scheduling distribution; the extra
    output changes no arithmetic on the default path). ``avail`` (sim
    dropout/churn) zeroes unavailable devices' probabilities before the
    draw.

    ``policy_id`` (a traced int32, ``scheduling.POLICY_IDS`` order) switches
    the stage to the FUSED dispatch the policy-vmapped lattice compiles: the
    probabilities come from ``scheduling_probs_by_id`` and the
    deterministic-policy weight rule is a value select instead of a Python
    branch. Per-cell values are bit-identical to the ``policy_id=None``
    string dispatch of the same policy — every branch's arithmetic is
    exactly the static version's, and both weight rules consume the same
    draw of the same ``k_sched``.
    """
    method = "topk" if cfg.sampler == "topk" else "sequential"
    if policy_id is None:
        probs = scheduling.scheduling_probs(
            cfg.policy, stats.norm, stats.var, h_abs, data_frac, dim,
            alpha, cfg.tx_power, noise_power,
        )
    else:
        probs = scheduling.scheduling_probs_by_id(
            policy_id, stats.norm, stats.var, h_abs, data_frac, dim,
            alpha, cfg.tx_power, noise_power,
        )
    if avail is not None:
        masked = probs * avail
        probs = safe_div(masked, jnp.sum(masked))

    if policy_id is None:
        if cfg.policy == "deterministic":
            sched = scheduling.sample_without_replacement(
                k_sched, probs, cfg.n_scheduled, method=method
            )
            rho = scheduling.deterministic_weights(sched, data_frac)
            mask = sched.mask
        elif cfg.sampler == "bernoulli":
            mask, pi = scheduling.sample_bernoulli(k_sched, probs, cfg.n_scheduled)
            rho = scheduling.bernoulli_weights(pi, data_frac)
        else:
            sched = scheduling.sample_without_replacement(
                k_sched, probs, cfg.n_scheduled, method=method
            )
            rho = scheduling.aggregation_weights(sched, probs, data_frac, cfg.n_scheduled)
            mask = sched.mask
        return (rho, mask, probs) if return_probs else (rho, mask)

    # fused dispatch: the policy is data, so the deterministic-vs-stochastic
    # weight rule is a select over values computed from the SAME draw (the
    # string path draws with the same key in either branch)
    is_det = policy_id == scheduling.DETERMINISTIC_ID
    sched = scheduling.sample_without_replacement(
        k_sched, probs, cfg.n_scheduled, method=method
    )
    rho_det = scheduling.deterministic_weights(sched, data_frac)
    if cfg.sampler == "bernoulli":
        mask_b, pi = scheduling.sample_bernoulli(k_sched, probs, cfg.n_scheduled)
        rho = jnp.where(is_det, rho_det, scheduling.bernoulli_weights(pi, data_frac))
        mask = jnp.where(is_det, sched.mask, mask_b)
    else:
        rho_seq = scheduling.aggregation_weights(
            sched, probs, data_frac, cfg.n_scheduled
        )
        rho = jnp.where(is_det, rho_det, rho_seq)
        mask = sched.mask
    return (rho, mask, probs) if return_probs else (rho, mask)


def aggregation_stage(
    cfg: POFLConfig,
    g: jnp.ndarray,
    rho: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    k_noise: jax.Array,
    noise_power,
    use_pallas: str | bool = "auto",
    model_shard: ModelShard | None = None,
    stats: aircomp.GradStats | None = None,
    dim: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Steps 5: transmit + AirComp aggregate per ``cfg.backend`` → (ŷ, e_com).

    ``jnp`` runs the exact reference chain; ``pallas_fused`` collapses the
    Eq. 5 normalize → Lemma-1 transmit scale → Eq. 7 superpose → Eq. 8
    denoise/denormalize into one pass over the gradient matrix
    (``kernels/aircomp``). Under the lattice's cell vmap the fused
    ``pallas_call`` batches into the trial-batched grid — the
    ``aircomp_fused_batch`` layout — without host-side dispatch.

    ``model_shard`` switches to the D-sharded route: ``g`` is then the
    padded block (``ModelShard.pad_features``), ``stats`` the psum'd
    statistics, ``dim`` the TRUE (unpadded) flat dimension — the noise draw
    stays the full-D draw of the same key (identical values to the
    unsharded path; only its placement is sharded) and the returned ŷ is
    still padded (slice ``[:dim]`` at the caller). ``e_com``'s closed form
    always uses the true ``dim``.
    """
    backend = AggregationBackend(cfg.backend)
    if model_shard is None:
        if backend is AggregationBackend.JNP:
            return aircomp.aircomp_aggregate(
                g, rho, h, mask, k_noise, cfg.tx_power, noise_power,
                simulate_physical=cfg.simulate_physical,
            )

        from repro.kernels.aircomp import aircomp_aggregate_fused  # late: kernels↔core

        stats = aircomp.local_stats(g)
        m_g, v_g = aircomp.global_stats(stats, rho, mask)
        h_abs = jnp.abs(h)
        a = aircomp.denoise_scalar(rho, h_abs, mask, cfg.tx_power)
        dim = g.shape[-1]
        z = jax.random.normal(k_noise, (dim,)) * jnp.sqrt(noise_power)
        coeff = mask * rho  # b_i h_i = ρ_i a exactly (Lemma-1 channel inversion)
        y_hat = aircomp_aggregate_fused(
            g, coeff, m_g, v_g, a, z, use_pallas=use_pallas
        )
        e_com = aircomp.distortion_closed_form(
            v_g, rho, h_abs, mask, dim, cfg.tx_power, noise_power
        )
        return y_hat, e_com

    if stats is None or dim is None:
        raise ValueError("model-sharded aggregation needs precomputed stats + dim")
    m_g, v_g = aircomp.global_stats(stats, rho, mask)
    h_abs = jnp.abs(h)
    a = aircomp.denoise_scalar(rho, h_abs, mask, cfg.tx_power)
    # same draw, same key, same values as the unsharded path — only the
    # padding tail (zeros) and the placement differ
    z = jax.random.normal(k_noise, (dim,)) * jnp.sqrt(noise_power)
    d_pad = g.shape[-1]
    if d_pad != dim:
        z = jnp.pad(z, (0, d_pad - dim))
    y_hat = _model_sharded_combine(
        cfg, model_shard, g, rho, h, mask, z, m_g, v_g, a, use_pallas
    )
    e_com = aircomp.distortion_closed_form(
        v_g, rho, h_abs, mask, dim, cfg.tx_power, noise_power
    )
    return y_hat, e_com


def apply_update_stage(
    cfg: POFLConfig, params, y_hat: jnp.ndarray, t,
    model_shard: ModelShard | None = None,
):
    """Step 6: w^{t+1} = w^t − η^t ŷ^t (flat update, re-raveled).

    Under a :class:`ModelShard` each updated leaf is constrained back to its
    model-sharded placement (``P(None, "model")`` on the last eligible dim)
    so the scan carry keeps a stable sharding across rounds instead of
    drifting to whatever layout the flat update left behind.
    """
    flat_params, unravel_p = ravel_pytree(params)
    new_params = unravel_p(flat_params - cfg.lr(t) * y_hat)
    if model_shard is not None:
        new_params = jax.tree.map(
            lambda leaf: jax.lax.with_sharding_constraint(
                leaf, model_shard.leaf_sharding(np.shape(leaf))
            ),
            new_params,
        )
    return new_params


# --------------------------------------------------------------------------
# the composed round
# --------------------------------------------------------------------------


def round_algorithm(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    data: DeviceData,
    cfg: POFLConfig,
    params,
    h: jnp.ndarray,
    k_batch: jax.Array,
    k_sched: jax.Array,
    k_noise: jax.Array,
    t: jnp.ndarray,
    noise_power: jnp.ndarray | float | None = None,
    alpha: jnp.ndarray | float | None = None,
    avail: jnp.ndarray | None = None,
    policy_id: jnp.ndarray | None = None,
    diagnostics: bool = False,
    model_shard: ModelShard | None = None,
    alg_state: AlgState | None = None,
    algorithm_id: jnp.ndarray | None = None,
    fault_round: jnp.ndarray | None = None,
) -> tuple[Any, AlgState | None, RoundMetrics]:
    """Steps 2–6 of Algorithm 1 for one round, given this round's channel ``h``.

    Returns ``(new_params, new_alg_state, metrics)``. ``alg_state`` is the
    per-device local-algorithm state (:class:`~repro.core.local_update.AlgState`
    in the engine's scan carry; ``None`` — the default and the only value the
    legacy path ever passes — flattens to an empty subtree and is returned
    unchanged). ``algorithm_id`` (traced int32, ``local_update.ALGORITHM_IDS``
    order) switches the local stage to the fused ``lax.switch`` dispatch the
    multi-algorithm lattice compiles; ``None`` keeps the static
    ``cfg.local_algorithm`` string dispatch — and the default
    ``fedavg``/``local_steps=1`` config traces the EXACT legacy program.

    Composes the four pipeline stages. ``noise_power`` / ``alpha`` default to
    the (static) config values but may be traced arrays — the simulation
    lattice vmaps over them. Everything structural (sampler, |S|, batch
    size, backend) stays static. The POLICY is static by default
    (``cfg.policy`` string dispatch) but becomes one more traced leaf when
    ``policy_id`` is given (``scheduling.POLICY_IDS`` order): the fused
    lattice vmaps over it, so every policy of a sweep shares ONE compiled
    program. Per-cell values are bit-identical between the two dispatches.

    ``avail`` is an optional (N,) 0/1 availability mask (sim dropout/churn
    scenarios): unavailable devices get zero scheduling probability this
    round. ``None`` (the default, and the only value the legacy path ever
    passes) skips the masking entirely, keeping the static-scenario
    trajectory bit-identical to the seed implementation.

    ``diagnostics`` (static, driven by ``ObsConfig.diagnostics``) adds the
    cheap per-round taps of :class:`repro.core.metrics.RoundDiagnostics` to
    the returned metrics. Off — the default — the traced program is
    bit-identical to the seed: no extra ops, ``metrics.diag is None``.

    ``model_shard`` (a :class:`ModelShard`, from an engine whose mesh has a
    ``"model"`` axis > 1) reroutes the D-elementwise hot path — stats,
    aggregation, params carry — through model-sharded ``shard_map`` blocks;
    ``None`` keeps the unsharded trace exactly.

    ``fault_round`` (traced int32 scalar, or ``None`` — the default and the
    only value every pre-existing path passes) is the deterministic
    fault-injection hook of ``repro.sim.resilience``: when the current round
    ``t`` equals it, the aggregate ŷ^t is poisoned to NaN *as a value select*
    — the fault point is input data, not trace structure, so a lattice with
    one poisoned cell runs the SAME compiled program as an unpoisoned one
    (``fault_round = -1`` never fires) and every other cell's lanes are
    bitwise unchanged. ``cfg.on_nonfinite`` decides what happens next:
    ``"propagate"`` (default) lets the NaN flow — the seed's exact program
    when ``fault_round`` is also None — while ``"skip"`` quarantines any
    non-finite aggregate (injected or organic): ``new_params`` and the
    AlgState hold their previous values via ``lax.cond`` and the round is
    counted on the returned :class:`~repro.core.metrics.RoundHealth` subtree
    (``metrics.health``; ``None`` under "propagate" — the empty-subtree
    trick, fourth application).
    """
    noise_power = cfg.noise_power if noise_power is None else noise_power
    alpha = cfg.alpha if alpha is None else alpha

    data_frac = data.data_frac

    if policy_id is None:
        noise_free = cfg.policy == "noisefree"
        agg_noise_power = 0.0 if noise_free else noise_power
    else:
        # traced policy: σ_z² = 0 for noisefree cells is a runtime select —
        # sqrt(0)·z and the 0-noise closed forms are exact, so values match
        # the static 0.0 of the string path bit for bit
        agg_noise_power = jnp.where(
            policy_id == scheduling.NOISEFREE_ID, 0.0, noise_power
        )

    # -- step 2: local updates (K SGD steps per device → delta) -------
    alg_state_in = alg_state  # pre-round state (the quarantine hold value)
    g, alg_state = local_update_stage(
        loss_fn, data, cfg, params, k_batch, t,
        alg_state=alg_state, algorithm_id=algorithm_id,
    )  # (N, D) — the legacy single gradient when fedavg/local_steps=1
    dim = g.shape[-1]

    # -- step 3: uploaded scalar statistics ---------------------------
    if model_shard is not None:
        # pad D to |model|·tile_d, place P(None, "model"); stats become
        # masked shard-local reductions + small psums over the model axis
        g = model_shard.pad_features(g, dim)
        stats = _model_sharded_local_stats(model_shard, g, dim)
    else:
        stats = aircomp.local_stats(g)

    # -- step 4: scheduling -------------------------------------------
    h_abs = jnp.abs(h)
    sched_out = scheduling_stage(
        cfg, stats, h_abs, data_frac, dim, alpha, noise_power, k_sched,
        avail=avail, policy_id=policy_id, return_probs=diagnostics,
    )
    rho, mask = sched_out[0], sched_out[1]

    # -- steps 5-6: AirComp aggregation + model update ----------------
    y_hat, e_com = aggregation_stage(
        cfg, g, rho, h, mask, k_noise, agg_noise_power,
        model_shard=model_shard, stats=stats, dim=dim,
    )
    if model_shard is not None:
        # ŷ comes back padded (its tail is sqrt(V_g)/a·0 + M_g, not zero) —
        # slice to the true D before the update and the norm tap
        y_hat = y_hat[:dim]
    if fault_round is not None:
        # deterministic NaN injection: a value select on the traced fault
        # point, so the no-fault program (fault_round = -1) is the same
        # executable and every unpoisoned lane is bitwise unchanged
        y_hat = jnp.where(
            t == jnp.asarray(fault_round, jnp.float32),
            jnp.full_like(y_hat, jnp.nan),
            y_hat,
        )
    # e_var on the padded g is exact: padded columns are zero in every term
    e_var = scheduling.global_update_variance(g, rho, mask, data_frac, cfg.n_scheduled)

    new_params = apply_update_stage(cfg, params, y_hat, t, model_shard=model_shard)
    health = None
    if cfg.on_nonfinite == "skip":
        # quarantine: a non-finite aggregate (injected or organic) must not
        # poison the carry — hold BOTH the params and the local-algorithm
        # state, i.e. the round never happened for the model. The PRNG chain
        # (engine carry) is untouched either way, so quarantined sweeps stay
        # deterministic.
        finite = jnp.all(jnp.isfinite(y_hat))
        new_params, alg_state = jax.lax.cond(
            finite,
            lambda upd, _prev: upd,
            lambda _upd, prev: prev,
            (new_params, alg_state),
            (params, alg_state_in),
        )
        health = RoundHealth(
            nonfinite=(~finite).astype(jnp.float32)
        )
    elif cfg.on_nonfinite != "propagate":
        raise ValueError(
            f"POFLConfig.on_nonfinite must be 'propagate' or 'skip', "
            f"got {cfg.on_nonfinite!r}"
        )

    a = aircomp.denoise_scalar(rho, h_abs, mask, cfg.tx_power)
    diag = None
    if diagnostics:
        _, v_g = aircomp.global_stats(stats, rho, mask)
        diag = diagnostics_taps(
            sched_out[2], stats.norm, v_g, a, h_abs, cfg.tx_power,
            agg_noise_power,
        )
    metrics = RoundMetrics(
        loss=jnp.zeros(()),  # filled by caller's eval if desired
        e_com=e_com,
        e_var=e_var,
        grad_norm=jnp.linalg.norm(y_hat),
        n_scheduled=jnp.sum(mask),
        a_scalar=a,
        diag=diag,
        health=health,
    )
    return new_params, alg_state, metrics


def make_round_step(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    data: DeviceData,
    channel: ChannelState,
    cfg: POFLConfig,
):
    """Build the jitted single-round step implementing Algorithm 1."""

    def round_step(params, key, t):
        k_batch, k_chan, k_sched, k_noise = jax.random.split(key, 4)
        h = channel.sample(k_chan)
        new_params, _, m = round_algorithm(
            loss_fn, data, cfg, params, h, k_batch, k_sched, k_noise, t
        )
        return new_params, m

    return jax.jit(round_step)


def run_pofl(
    loss_fn,
    params0,
    data: DeviceData,
    cfg: POFLConfig,
    n_rounds: int,
    eval_fn: Callable[[Any], tuple[float, float]] | None = None,
    eval_every: int = 5,
    channel_cfg: ChannelConfig | None = None,
) -> tuple[Any, History]:
    """Run Algorithm 1 for ``n_rounds`` and return (params, history).

    Compatibility wrapper over ``repro.sim.engine.SimEngine``: the T-round
    loop is a single-static-length active-mask ``lax.scan`` chunked at the
    evaluation boundaries, so metrics only sync to host once per eval
    interval instead of once per round. The trajectory is identical (same
    PRNG key discipline, same round body) to the historical per-round Python
    loop — see tests/test_sim.py.

    Engines (and their jitted scans) are cached across calls keyed by
    ``(task, cfg-minus-seed, backend)`` — a repeat call with the same config
    (any seed) reuses the compiled program with zero new traces
    (``repro.sim.engine.engine_cache_stats``).
    """
    from repro.sim.engine import cached_engine  # late import: sim builds on core

    engine = cached_engine(loss_fn, data, cfg, channel_cfg=channel_cfg)
    return engine.run_with_history(
        params0, n_rounds, eval_fn=eval_fn, eval_every=eval_every,
        seed=cfg.seed,
    )
