"""Convergence-bound diagnostics (Theorem 1 terms) tracked during training,
plus the in-trace observability taps (:class:`RoundDiagnostics`) that
``ObsConfig(diagnostics=True)`` compiles into the lattice program."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.numerics import EPS, safe_div


class RoundMetrics(NamedTuple):
    """Per-round diagnostics matching the Thm. 1 decomposition."""

    loss: jnp.ndarray          # global train loss f(w^t)
    e_com: jnp.ndarray         # Eq. 15 closed-form communication distortion
    e_var: jnp.ndarray         # realized global update variance
    grad_norm: jnp.ndarray     # ||ŷ^t||
    n_scheduled: jnp.ndarray   # realized |S^t|
    a_scalar: jnp.ndarray      # denoise scalar a^t (Lemma 1)
    diag: Any = None           # RoundDiagnostics when ObsConfig asks, else None
    health: Any = None         # RoundHealth when POFLConfig.on_nonfinite="skip"


class RoundHealth(NamedTuple):
    """The non-finite quarantine taps (``POFLConfig.on_nonfinite="skip"``).

    Fourth application of the ``diag=None`` empty-subtree trick: carried as an
    optional record subtree that is ``None`` — an EMPTY pytree, zero new ops,
    every pinned trajectory bitwise unchanged — under the default
    ``on_nonfinite="propagate"``. Under ``"skip"`` it counts, per round, a 0/1
    "the aggregate ŷ^t contained a non-finite entry and the round was
    quarantined" flag (the engine's scan stacks it to a (T,) curve; the
    lattice to the full grid).
    """

    nonfinite: jnp.ndarray  # 1.0 when ŷ^t had any non-finite entry, else 0.0


def zero_round_health() -> RoundHealth:
    """The inactive-branch all-zero health record (mirrors
    :meth:`RoundHealth`'s structure exactly — the engine's padded-scan
    ``lax.cond`` needs both branches identical)."""
    return RoundHealth(nonfinite=jnp.zeros((), jnp.float32))


def bound_objective(e_com: jnp.ndarray, e_var: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """The (P1) objective: (1+α)·e_com + (1+1/α)·e_var."""
    return (1.0 + alpha) * e_com + (1.0 + 1.0 / alpha) * e_var


class RoundDiagnostics(NamedTuple):
    """Cheap per-round scalar taps computed INSIDE the compiled program.

    Carried as an extra record-pytree subtree when
    ``ObsConfig(diagnostics=True)`` — a handful of reductions over (N,)
    vectors per round, negligible next to the (N, D) gradient work. ``None``
    (diagnostics off) flattens to an empty subtree, so the off-path record
    pytree has exactly the seed's leaves.
    """

    noise_eff: jnp.ndarray        # V_g σ_z² / a² — per-entry noise power the
    #                               model update actually absorbs after the
    #                               Eq. 8 denoise/denormalize reweighting
    sched_entropy: jnp.ndarray    # -Σ p log p of the scheduling distribution
    eps_clamps: jnp.ndarray       # how many EPS guard sites sat at the floor
    grad_norm_spread: jnp.ndarray  # std_i ||g_i|| — device gradient dispersion


def diagnostics_taps(
    probs: jnp.ndarray,
    grad_norms: jnp.ndarray,
    v_g: jnp.ndarray,
    a_scalar: jnp.ndarray,
    h_abs: jnp.ndarray,
    tx_power: float,
    noise_power,
) -> RoundDiagnostics:
    """Compute the :class:`RoundDiagnostics` taps from round intermediates.

    ``noise_eff`` inverts the aggregation reweighting: the receiver noise
    ``z`` enters the model update as ``sqrt(V_g)·z/a`` (Eq. 8), so its
    effective per-entry power is ``V_g σ_z² / a²`` — the distortion Eq. 15
    divided by D, realized rather than worst-case. ``eps_clamps`` counts
    guard sites at the :data:`~repro.core.numerics.EPS` floor this round
    (deep-fade channels, underflowed probabilities, degenerate V_g): a
    persistently non-zero count means the run is riding the numerical
    guards, not the physics.
    """
    a_sq = jnp.maximum(a_scalar * a_scalar, EPS)
    noise_eff = safe_div(jnp.maximum(v_g, EPS) * noise_power, a_sq)
    p = probs / jnp.maximum(jnp.sum(probs), EPS)
    sched_entropy = -jnp.sum(jnp.where(p > 0.0, p * jnp.log(jnp.maximum(p, EPS)), 0.0))
    eps_clamps = (
        jnp.sum((tx_power * h_abs * h_abs <= EPS).astype(jnp.float32))
        + jnp.sum((probs <= EPS).astype(jnp.float32))
        + (v_g <= EPS).astype(jnp.float32)
    )
    grad_norm_spread = jnp.std(grad_norms)
    return RoundDiagnostics(
        noise_eff=noise_eff,
        sched_entropy=sched_entropy,
        eps_clamps=eps_clamps,
        grad_norm_spread=grad_norm_spread,
    )
