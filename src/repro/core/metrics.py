"""Convergence-bound diagnostics (Theorem 1 terms) tracked during training."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class RoundMetrics(NamedTuple):
    """Per-round diagnostics matching the Thm. 1 decomposition."""

    loss: jnp.ndarray          # global train loss f(w^t)
    e_com: jnp.ndarray         # Eq. 15 closed-form communication distortion
    e_var: jnp.ndarray         # realized global update variance
    grad_norm: jnp.ndarray     # ||ŷ^t||
    n_scheduled: jnp.ndarray   # realized |S^t|
    a_scalar: jnp.ndarray      # denoise scalar a^t (Lemma 1)


def bound_objective(e_com: jnp.ndarray, e_var: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """The (P1) objective: (1+α)·e_com + (1+1/α)·e_var."""
    return (1.0 + alpha) * e_com + (1.0 + 1.0 / alpha) * e_var
