"""PO-FL core: channel model, AirComp signal chain, scheduling, simulator."""
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.pofl import (
    DeviceData,
    History,
    POFLConfig,
    make_round_step,
    round_algorithm,
    run_pofl,
)
from repro.core.scheduling import POLICIES, Schedule, scheduling_probs

__all__ = [
    "ChannelConfig",
    "ChannelState",
    "DeviceData",
    "History",
    "POFLConfig",
    "POLICIES",
    "Schedule",
    "make_round_step",
    "round_algorithm",
    "run_pofl",
    "scheduling_probs",
]
