"""PO-FL core: channel model, AirComp signal chain, scheduling, simulator."""
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.local_update import (
    ALGORITHM_IDS,
    ALGORITHMS,
    AlgState,
    algorithm_id,
    local_update_stage,
)
from repro.core.numerics import EPS, eps_guard, safe_div
from repro.core.pofl import (
    BACKENDS,
    AggregationBackend,
    DeviceData,
    History,
    POFLConfig,
    aggregation_stage,
    apply_update_stage,
    local_gradient_stage,
    make_round_step,
    round_algorithm,
    run_pofl,
    scheduling_stage,
)
from repro.core.scheduling import POLICIES, Schedule, scheduling_probs

__all__ = [
    "ALGORITHM_IDS",
    "ALGORITHMS",
    "AggregationBackend",
    "AlgState",
    "BACKENDS",
    "ChannelConfig",
    "ChannelState",
    "DeviceData",
    "EPS",
    "History",
    "POFLConfig",
    "POLICIES",
    "Schedule",
    "aggregation_stage",
    "algorithm_id",
    "apply_update_stage",
    "eps_guard",
    "local_gradient_stage",
    "local_update_stage",
    "make_round_step",
    "round_algorithm",
    "run_pofl",
    "safe_div",
    "scheduling_probs",
    "scheduling_stage",
]
