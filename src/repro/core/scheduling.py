"""Device scheduling policies (paper Sec. IV + Sec. V baselines).

Policies produce *single-draw* scheduling probabilities p_i^t (Σp=1); the
multi-device schedule is drawn by repeated sampling **without replacement**
with the Eq. 36 renormalization, and aggregation weights follow Eq. 37.

Implemented policies:
  * ``pofl``          — Eq. 34/35 (channel + gradient-importance aware, ours)
  * ``importance``    — p_i ∝ (m_i/M)·||g_i||          [Remark 2 / refs 13,22]
  * ``channel``       — p_i ∝ |h_i|²                   [Remark 2 / refs 13,24]
  * ``noisefree``     — Eq. 34/35 with σ_z² = 0 (idealized benchmark)
  * ``deterministic`` — uniform random subset, direct (biased) aggregation
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.numerics import EPS, eps_guard, safe_div

POLICIES = ("pofl", "importance", "channel", "noisefree", "deterministic")

# Integer ids for the traced-dispatch path (`scheduling_probs_by_id`): the id
# IS the index into the `lax.switch` branch table, so this order is part of
# the traced program's contract — append new policies, never reorder.
POLICY_IDS = {name: i for i, name in enumerate(POLICIES)}
NOISEFREE_ID = POLICY_IDS["noisefree"]
DETERMINISTIC_ID = POLICY_IDS["deterministic"]


def policy_id(policy: str) -> int:
    """The integer id of ``policy`` for the traced dispatch path."""
    try:
        return POLICY_IDS[policy]
    except KeyError:
        raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")


def pofl_q(
    grad_norms: jnp.ndarray,
    grad_vars: jnp.ndarray,
    h_abs: jnp.ndarray,
    data_frac: jnp.ndarray,
    dim: int,
    alpha: float,
    tx_power: float,
    noise_power: float,
) -> jnp.ndarray:
    """Eq. 35:  Q_i = sqrt((1+α)·Ṽ_g D σ_z² m_i²/(P|h_i|²M²) + (1+1/α)·m_i²||g_i||²/M²).

    Args:
      grad_norms: (N,) uploaded ||g_i||.
      grad_vars:  (N,) uploaded V_i (per-device gradient entry variance).
      h_abs:      (N,) |h_i| this round.
      data_frac:  (N,) m_i / M.
    """
    v_g_tilde = jnp.sum(data_frac * grad_vars)
    # guard the DENOMINATOR, not |h|: eps_guard(h)**2 underflows to exactly 0
    # in float32 for |h| ≲ 1e-19, which turns a deep fade into inf/NaN probs.
    # For every physical |h| (h² ≥ EPS) this is bit-identical to dividing by
    # tx_power·|h|² — pinned trajectories are unchanged.
    com_term = safe_div(
        (1.0 + alpha) * v_g_tilde * dim * noise_power * data_frac**2,
        tx_power * h_abs**2,
    )
    var_term = (1.0 + 1.0 / alpha) * data_frac**2 * grad_norms**2
    return jnp.sqrt(com_term + var_term)


def scheduling_probs(
    policy: str,
    grad_norms: jnp.ndarray,
    grad_vars: jnp.ndarray,
    h_abs: jnp.ndarray,
    data_frac: jnp.ndarray,
    dim: int,
    alpha: float,
    tx_power: float,
    noise_power: float,
) -> jnp.ndarray:
    """Single-draw probabilities p_i (Eq. 34 for pofl; Remark 2 for baselines)."""
    if policy == "pofl":
        q = pofl_q(grad_norms, grad_vars, h_abs, data_frac, dim, alpha, tx_power, noise_power)
    elif policy == "noisefree":
        q = pofl_q(grad_norms, grad_vars, h_abs, data_frac, dim, alpha, tx_power, 0.0)
    elif policy == "importance":
        q = data_frac * grad_norms
    elif policy == "channel":
        q = h_abs**2
    elif policy == "deterministic":
        q = jnp.ones_like(h_abs)
    else:  # pragma: no cover - guarded by POLICIES
        raise ValueError(f"unknown policy {policy!r}")
    q = eps_guard(q)
    return q / jnp.sum(q)


def scheduling_probs_by_id(
    policy_id: jnp.ndarray,
    grad_norms: jnp.ndarray,
    grad_vars: jnp.ndarray,
    h_abs: jnp.ndarray,
    data_frac: jnp.ndarray,
    dim: int,
    alpha,
    tx_power: float,
    noise_power,
) -> jnp.ndarray:
    """:func:`scheduling_probs` with the policy as a TRACED integer.

    ``policy_id`` indexes the ``lax.switch`` branch table built from
    ``POLICIES`` order (see ``POLICY_IDS``); each branch computes exactly the
    same unnormalized score ``q`` as the string-dispatch version, and the
    eps-guard + normalization are shared, so per-call values are
    bit-identical to ``scheduling_probs(POLICIES[policy_id], ...)``. Under a
    ``vmap`` over cells the switch degenerates to compute-all-and-select —
    the price of fusing every policy into ONE compiled lattice program
    (``repro.sim.lattice``) instead of one compile per policy.
    """

    def _q_pofl(norms, gvars, h, frac, a, s2):
        return pofl_q(norms, gvars, h, frac, dim, a, tx_power, s2)

    def _q_noisefree(norms, gvars, h, frac, a, s2):
        del s2
        return pofl_q(norms, gvars, h, frac, dim, a, tx_power, 0.0)

    def _q_importance(norms, gvars, h, frac, a, s2):
        del gvars, h, a, s2
        return frac * norms

    def _q_channel(norms, gvars, h, frac, a, s2):
        del norms, gvars, frac, a, s2
        return h**2

    def _q_deterministic(norms, gvars, h, frac, a, s2):
        del norms, gvars, frac, a, s2
        return jnp.ones_like(h)

    branches = {
        "pofl": _q_pofl,
        "importance": _q_importance,
        "channel": _q_channel,
        "noisefree": _q_noisefree,
        "deterministic": _q_deterministic,
    }
    q = jax.lax.switch(
        policy_id,
        [branches[name] for name in POLICIES],
        grad_norms, grad_vars, h_abs, data_frac, alpha, noise_power,
    )
    q = eps_guard(q)
    return q / jnp.sum(q)


class Schedule(NamedTuple):
    """One round's draw: indices Y_{t,k}, their step-k renormalized probs q_k,
    and the 0/1 device mask.

    When fewer than ``n_scheduled`` devices are selectable (some probs are
    exactly 0 — e.g. sim dropout masking), the realized |S^t| is clamped to
    the selectable count: surplus draws carry the sentinel ``indices=-1``
    with ``step_probs=inf`` (→ zero Eq. 37 weight) and leave the mask
    untouched.
    """

    indices: jnp.ndarray  # (S,) int32 — Y_{t,1..S}; -1 = no draw (see above)
    step_probs: jnp.ndarray  # (S,) — q^t_{Y_{t,k}} at the k-th selection (Eq. 36)
    mask: jnp.ndarray  # (N,) float — 1{i ∈ S^t}


def sample_without_replacement(
    key: jax.Array, probs: jnp.ndarray, n_scheduled: int,
    method: str = "sequential",
) -> Schedule:
    """Sampling without replacement with Eq. 36 renormalization.

    At step k the live probabilities are q_i = p_i / (1 - Σ_{j<k} p_{Y_j})
    for unselected i (0 otherwise); we record q_{Y_k} for the Eq. 37 weights.

    Devices with exactly zero probability are never drafted: once the
    selectable mass is exhausted the remaining draws are no-ops (the
    ``Schedule`` sentinel described above) instead of drafting a prob-0
    device whose Eq. 37 weight 1/q would explode.

    ``method`` selects the draw implementation:

      * ``"sequential"`` (default) — the S-step ``lax.scan`` of categorical
        draws; the seed implementation, pinned trajectories depend on its
        exact PRNG consumption.
      * ``"topk"`` — one Gumbel-perturbed-logit top-k (no scan): drawing the
        top-S of ``log p_i + Gumbel_i`` is distributionally identical to the
        S sequential Eq. 36 draws (the Gumbel top-k trick), and the ordered
        indices reconstruct the same ``step_probs``. A different PRNG stream
        (one Gumbel vector vs S categorical keys), so realized draws differ
        sample-by-sample from ``"sequential"`` — opt in where only the LAW
        matters (fresh sweeps), never under pinned trajectories.
    """
    n = probs.shape[0]

    if method == "topk":
        selectable = probs > 0
        logits = jnp.where(selectable, jnp.log(eps_guard(probs)), -jnp.inf)
        perturbed = logits + jax.random.gumbel(key, (n,))
        # top_k caps at n; draws beyond that are sentinels anyway (the scan
        # path likewise clamps an over-subscribed n_scheduled > n)
        _, order = jax.lax.top_k(perturbed, min(n_scheduled, n))
        if n_scheduled > n:
            order = jnp.concatenate(
                [order, jnp.zeros((n_scheduled - n,), order.dtype)]
            )
        n_live = jnp.sum(selectable.astype(jnp.int32))
        real = jnp.arange(n_scheduled) < n_live  # clamp like the scan path
        indices = jnp.where(real, order, -1).astype(jnp.int32)
        safe = jnp.maximum(indices, 0)
        p_sel = jnp.where(real, probs[safe], 0.0)
        cum_prev = jnp.concatenate(
            [jnp.zeros((1,), p_sel.dtype), jnp.cumsum(p_sel)[:-1]]
        )
        step_probs = jnp.where(real, safe_div(p_sel, 1.0 - cum_prev), jnp.inf)
        mask = jnp.zeros(n).at[safe].add(jnp.where(real, 1.0, 0.0))
        return Schedule(indices=indices, step_probs=step_probs, mask=mask)
    if method != "sequential":
        raise ValueError(f"unknown sampling method {method!r}")

    def step(carry, k_key):
        mask, cum_p = carry
        selectable = ((1.0 - mask) > 0) & (probs > 0)
        any_live = jnp.sum(jnp.where(selectable, probs, 0.0)) > 0
        q = safe_div(jnp.where(selectable, probs, 0.0), 1.0 - cum_p)
        # Gumbel-max draw over the renormalized distribution (scale-invariant,
        # so the shared denominator does not change the draw — but q_k does
        # enter the aggregation weights).
        logits = jnp.where(selectable, jnp.log(eps_guard(probs)), -jnp.inf)
        drawn = jax.random.categorical(k_key, logits)  # garbage if ~any_live
        safe = jnp.maximum(drawn, 0)
        idx = jnp.where(any_live, drawn, -1)
        q_k = jnp.where(any_live, q[safe], jnp.inf)
        mask = jnp.where(any_live, mask.at[safe].set(1.0), mask)
        cum_p = cum_p + jnp.where(any_live, probs[safe], 0.0)
        return (mask, cum_p), (idx, q_k)

    keys = jax.random.split(key, n_scheduled)
    (mask, _), (indices, step_probs) = jax.lax.scan(
        step, (jnp.zeros(n), jnp.zeros(())), keys
    )
    return Schedule(indices=indices.astype(jnp.int32), step_probs=step_probs, mask=mask)


def aggregation_weights(
    schedule: Schedule, probs: jnp.ndarray, data_frac: jnp.ndarray, n_scheduled: int
) -> jnp.ndarray:
    """Per-device aggregation weights ρ_i scattered to an (N,) vector.

    Eq. 37: ŷ uses (1/|S|)·m_i/(M·q_{Y_k}) for the k-th selected device.
    For |S| = 1 this reduces to the Eq. 16 weight m_i/(M p_i).

    |S| is the *realized* draw count: it equals ``n_scheduled`` except when
    the sampler clamped (sentinel draws carry step_probs=inf → zero w_k, and
    their -1 indices scatter that zero harmlessly onto the last device).
    """
    del probs, n_scheduled
    n = data_frac.shape[0]
    w_k = safe_div(data_frac[schedule.indices], schedule.step_probs)
    # Explicitly zero the sentinel draws: with heterogeneous data_frac the
    # gathered data_frac[-1] can itself be anything, and an all-dropped round
    # (every index -1) must scatter exactly zero weight everywhere.
    w_k = jnp.where(schedule.indices >= 0, w_k, 0.0)
    n_drawn = jnp.sum((schedule.indices >= 0).astype(w_k.dtype))
    w_k = w_k / jnp.maximum(n_drawn, 1.0)
    return jnp.zeros(n).at[schedule.indices].add(w_k)


def bernoulli_inclusion_probs(probs: jnp.ndarray, n_scheduled: int) -> jnp.ndarray:
    """Inclusion probabilities π_i with Σπ = S and π_i ∝ p_i where possible.

    π_i = min(1, c·p_i) with c chosen so Σπ_i = S (Poisson/conditional-Poisson
    style sampling with a target expected size). Solved by bisection on c —
    monotone, a few fixed iterations suffice (runs under jit).
    """

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        total = jnp.sum(jnp.minimum(1.0, mid * probs))
        lo = jnp.where(total < n_scheduled, mid, lo)
        hi = jnp.where(total < n_scheduled, hi, mid)
        return lo, hi

    n = probs.shape[0]
    # bracket on the smallest POSITIVE prob: zero entries (e.g. unavailable
    # devices under sim dropout) stay at π=0 for any c and must not blow the
    # bisection bracket up to 1/1e-30.
    min_pos = jnp.min(jnp.where(probs > 0, probs, jnp.inf))
    hi0 = jnp.asarray(safe_div(n, min_pos))
    lo, hi = jax.lax.fori_loop(0, 50, body, (jnp.zeros(()), hi0))
    c = 0.5 * (lo + hi)
    return jnp.clip(c * probs, EPS, 1.0)


def sample_bernoulli(
    key: jax.Array, probs: jnp.ndarray, n_scheduled: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Beyond-paper variant (PO-FL-B): independent Bernoulli scheduling.

    Device i is scheduled independently with π_i (E[|S|] = n_scheduled) and
    reweighted by m_i/(M π_i) — a Horvitz–Thompson estimator that is *exactly*
    unbiased for any |S|, unlike the Eq. 37 sequential estimator (which is
    exactly unbiased only for |S| = 1; see tests/test_scheduling.py).

    Returns (mask, pi).
    """
    pi = bernoulli_inclusion_probs(probs, n_scheduled)
    mask = (jax.random.uniform(key, probs.shape) < pi).astype(jnp.float32)
    return mask, pi


def bernoulli_weights(pi: jnp.ndarray, data_frac: jnp.ndarray) -> jnp.ndarray:
    """Horvitz–Thompson weights ρ_i = m_i/(M π_i) (applied with the mask)."""
    return safe_div(data_frac, pi)


def deterministic_weights(schedule: Schedule, data_frac: jnp.ndarray) -> jnp.ndarray:
    """Baseline direct aggregation: m_i / Σ_{j∈S} m_j on the selected set (biased).

    An all-dropped round (empty mask) yields all-zero weights — the eps floor
    keeps the 0/0 finite for any data_frac, uniform or not.
    """
    sel = schedule.mask * data_frac
    return safe_div(sel, jnp.sum(sel))


def global_update_variance(
    g: jnp.ndarray, rho: jnp.ndarray, mask: jnp.ndarray, data_frac: jnp.ndarray,
    n_scheduled: int,
) -> jnp.ndarray:
    """e_var (Thm. 1): ||Σ_{i∈S} ρ_i g_i − |S|·Σ_j (m_j/M) g_j||²  with ρ=m/(Mp).

    Note: under the Eq. 37 convention (weights already divided by |S|) the
    comparison target is the plain global gradient; we use the Eq. 37-scaled
    weights so the target is Σ_j (m_j/M) g_j.
    """
    est = jnp.sum((rho * mask)[:, None] * g, axis=0)
    target = jnp.sum(data_frac[:, None] * g, axis=0)
    del n_scheduled
    return jnp.sum((est - target) ** 2)
