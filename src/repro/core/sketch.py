"""JVP-sketched per-device gradient statistics (beyond-paper optimization).

Algorithm 1 needs every device's gradient scalars (M_i, V_i, ‖g_i‖) *before*
scheduling. At paper scale that's a vmap over 30 devices; at production scale
(100M–123B parameters, FL device = data-parallel slice) materializing
per-device gradients costs n_dev full backward passes.

Observation: all three scalars are functions of inner products g_i·v —
 *directional derivatives* of the per-device loss vector, computable for ALL
devices simultaneously with ONE forward-mode JVP:

    jvp(L, params, v)[1][i] = g_i · v     where L(params) = (L_1, ..., L_N)

  * M_i  = (g_i · 1) / D                      — exact, one JVP with v = 1
  * ‖g_i‖² = E_{v~N(0,I)}[(g_i·v)²]           — Hutchinson estimate, k probes
  * V_i  = ‖g_i‖²/D − M_i²                    — derived

Cost: (k+1) JVPs ≈ (k+1)·2 forward passes, independent of n_dev — versus
n_dev backward passes for the exact path. Unbiased (so Lemma 2 still holds
in expectation over probes); variance ∝ 1/k. Validated against exact stats
in tests/test_sketch.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aircomp import GradStats


def sketch_device_stats(
    per_device_loss: Callable,
    params,
    key: jax.Array,
    n_probes: int = 4,
) -> GradStats:
    """Estimate (M_i, V_i, ‖g_i‖) for every FL device.

    Args:
      per_device_loss: params -> (n_devices,) loss vector (one scalar per
        FL device, each the mean loss over that device's local examples).
      params: model parameters pytree.
      key: PRNG key for the Hutchinson probes.
      n_probes: number of random probes for the norm estimate.
    """
    dim = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))

    # exact per-device gradient mean: one JVP along the all-ones direction
    ones = jax.tree.map(jnp.ones_like, params)
    _, dots_ones = jax.jvp(per_device_loss, (params,), (ones,))
    mean = dots_ones / dim  # (n_devices,)

    # Hutchinson norm estimate: k probes v ~ N(0, I)
    def one_probe(k):
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(k, len(leaves))
        v = jax.tree.unflatten(
            treedef,
            [jax.random.normal(kk, l.shape, l.dtype) for kk, l in zip(keys, leaves)],
        )
        _, dots = jax.jvp(per_device_loss, (params,), (v,))
        return dots**2

    sq = jax.lax.map(one_probe, jax.random.split(key, n_probes))
    norm_sq = jnp.mean(sq, axis=0)  # (n_devices,)
    var = jnp.maximum(norm_sq / dim - mean**2, 0.0)
    return GradStats(mean=mean, var=var, norm=jnp.sqrt(norm_sq))


def exact_device_stats(
    per_device_grad: Callable,
    params,
    n_devices: int,
) -> tuple[GradStats, object]:
    """Faithful path: sequential per-device backwards, accumulating stats
    AND the stacked flat gradients are never materialized — only the stats
    and (optionally) a caller-weighted running sum.

    Args:
      per_device_grad: (params, i) -> grads pytree for FL device i.
    Returns (stats, grads_by_device) where grads_by_device is a function
    i -> grads (recomputed; use sketch mode to avoid this cost).
    """

    def one(i):
        g = per_device_grad(params, i)
        leaves = jax.tree.leaves(g)
        total = sum(int(jnp.size(l)) for l in leaves)
        s = sum(jnp.sum(l.astype(jnp.float32)) for l in leaves)
        sq = sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
        mean = s / total
        return mean, jnp.maximum(sq / total - mean**2, 0.0), jnp.sqrt(sq)

    means, variances, norms = jax.lax.map(one, jnp.arange(n_devices))
    return GradStats(mean=means, var=variances, norm=norms), None
