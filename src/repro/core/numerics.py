"""Shared numerical-safety helpers for the PO-FL math.

The scheduling/AirComp equations divide by quantities that can underflow to
exactly zero (|h_i| of a deep fade, renormalized probabilities of an
all-dropped round, π_i of a never-included device). Every such site guards
with the same floor so the guarded value — and therefore the seed-pinned
trajectories — is identical everywhere: ``EPS = 1e-30``, far below any
physically meaningful channel gain or probability, merely keeping IEEE
division finite.
"""
from __future__ import annotations

import jax.numpy as jnp

# The one epsilon. Changing it changes pinned trajectories — don't.
EPS = 1e-30


def eps_guard(x, eps: float = EPS):
    """Clamp ``x`` away from zero: ``max(x, eps)`` elementwise."""
    return jnp.maximum(x, eps)


def safe_div(num, den, eps: float = EPS):
    """``num / max(den, eps)`` — finite even when ``den`` underflows to 0."""
    return num / jnp.maximum(den, eps)
