"""Blocked flash attention — Pallas TPU kernel (online softmax).

Grid: (batch, q_head, n_q_blocks, n_kv_blocks) — the kv dimension innermost
and sequential, carrying running (max, denom, accumulator) in VMEM scratch.
GQA is handled in the BlockSpec index maps: head h reads kv head h // rep,
so K/V blocks are fetched once per query-head group member without a
materialized repeat.

VMEM working set per step: q (Bq, dh) + k,v (Bk, dh) + acc (Bq, dh) +
softmax stats (Bq, 128 lanes) — with Bq=Bk=256 and dh=128 this is ~0.6 MB,
far under the ~16 MB/core VMEM budget, leaving room for double buffering.
MXU alignment: Bq, Bk, dh multiples of 128 (dh is padded if needed).

Causal + sliding-window masking is positional: absolute positions derive
from the block indices, so fully-masked kv blocks are SKIPPED via pl.when
(block-sparse early-out — this is where the causal 2× and the sliding-window
O(S·W) savings come from).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, block_q: int, block_k: int,
    causal: bool, sliding_window: Optional[int], q_offset: int, n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = qi * block_q + q_offset  # absolute position of first query row
    k_start = ki * block_k

    # ---- block-level early-out ------------------------------------------
    # earliest query in block attends latest key?  q_abs_max >= k_start
    relevant = True
    if causal:
        relevant = (q_start + block_q - 1) >= k_start
    if sliding_window is not None:
        # latest query still sees earliest key: k_end > q_start - window
        relevant = relevant & ((k_start + block_k) > (q_start - sliding_window))

    @pl.when(ki == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (Bq, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (Bk, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = q @ k.T  # (Bq, Bk) — MXU

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if sliding_window is not None:
            mask = mask & (cols > rows - sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                  # (Bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])       # (Bq, Bk)
        # fully-masked rows: exp(NEG_INF - NEG_INF) = 1 would poison the sum
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)       # rescale factor for old state
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + p @ v
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "sliding_window", "q_offset", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,  # (b, sq, h, dh)
    k: jnp.ndarray,  # (b, sk, kv, dh)
    v: jnp.ndarray,  # (b, sk, kv, dh)
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked online-softmax attention. Returns (b, sq, h, dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, sliding_window=sliding_window,
        q_offset=q_offset, n_kv_blocks=nk,
    )

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, block_q, 1, dh), lambda bi, hi, qi, ki: (bi, qi, hi, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, dh), lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, dh), lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, dh), lambda bi, hi, qi, ki: (bi, qi, hi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
