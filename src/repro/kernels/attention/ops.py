"""Public op: flash attention with backend dispatch."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.attention.kernel import flash_attention
from repro.kernels.attention.ref import mha_ref


def attention(
    q, k, v, *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
    use_pallas: str | bool = "auto",
    block_q: int = 256,
    block_k: int = 256,
):
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention(
            q, k, v, causal=causal, sliding_window=sliding_window,
            q_offset=q_offset, block_q=block_q, block_k=block_k,
        )
    return mha_ref(q, k, v, causal=causal, sliding_window=sliding_window, q_offset=q_offset)


__all__ = ["attention", "flash_attention", "mha_ref"]
