from repro.kernels.attention.ops import attention, flash_attention, mha_ref

__all__ = ["attention", "flash_attention", "mha_ref"]
