"""Pure-jnp oracle for the flash-attention kernel: plain masked softmax
attention with GQA broadcast, causal and sliding-window masks."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def mha_ref(
    q: jnp.ndarray,  # (b, sq, h, dh)
    k: jnp.ndarray,  # (b, sk, kv, dh)
    v: jnp.ndarray,  # (b, sk, kv, dh)
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Returns (b, sq, h, dh). Query position i attends keys j with
    j ≤ i + q_offset (causal) and j > i + q_offset − window (sliding)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, dh)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) / math.sqrt(dh)

    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = mask & (kj <= qi)
    if sliding_window is not None:
        mask = mask & (kj > qi - sliding_window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)

    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, sq, h, dh)
