from repro.kernels.aircomp.kernel import DEFAULT_TILE_D
from repro.kernels.aircomp.ops import (
    aircomp_aggregate_fused,
    aircomp_aggregate_fused_batch,
    aircomp_fused,
    aircomp_fused_batch,
    aircomp_fused_batch_ref,
    aircomp_fused_ref,
)

__all__ = [
    "DEFAULT_TILE_D",
    "aircomp_aggregate_fused",
    "aircomp_aggregate_fused_batch",
    "aircomp_fused",
    "aircomp_fused_batch",
    "aircomp_fused_batch_ref",
    "aircomp_fused_ref",
]
