from repro.kernels.aircomp.ops import (
    aircomp_aggregate_fused,
    aircomp_fused,
    aircomp_fused_ref,
)

__all__ = ["aircomp_aggregate_fused", "aircomp_fused", "aircomp_fused_ref"]
