"""Pure-jnp oracle for the fused AirComp aggregation kernel.

Computes the full Eq. 5→8 physical signal chain in one expression:

    ŷ[d] = Σ_i mask_i · ρ_i · (g_i[d] − M_g) + (sqrt(V_g)/a) · z[d] + M_g·Σ_i mask_i·ρ_i·0 ...

More precisely (matching core/aircomp.aircomp_aggregate simulate_physical=True
with real-valued effective channel after Lemma-1 inversion):

    s_i[d]  = (g_i[d] − M_g) / sqrt(V_g)                       (Eq. 5)
    y~[d]   = Σ_i mask_i · ρ_i · a · s_i[d] + z[d]             (Eq. 7, b_i h_i = ρ_i a)
    ŷ[d]    = sqrt(V_g)/a · y~[d] + M_g                        (Eq. 8)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import eps_guard


def aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z):
    """Trial-batched oracle: leading (n_trials,) axis on every argument.

    vmap of :func:`aircomp_fused_ref` — the reference for the batched Pallas
    kernel serving whole lattice batches.
    """
    return jax.vmap(aircomp_fused_ref)(g, coeff, m_g, v_g, a, z)


def aircomp_fused_ref(g, coeff, m_g, v_g, a, z):
    """Args:
      g:     (n_devices, D) stacked local gradients
      coeff: (n_devices,)   mask_i · ρ_i
      m_g, v_g, a: scalars  (global mean/variance, denoise scalar)
      z:     (D,)           receiver noise ~ N(0, σ_z²)
    Returns ŷ: (D,)

    ``a`` is cancelled algebraically in the signal term — exactly as the
    Pallas kernel does — so an empty scheduled set (a=inf from the min over
    nothing, coeff all zero) stays finite: the naive a·s → (…)/a composition
    would produce 0·inf = NaN there.
    """
    sqrt_vg = jnp.sqrt(eps_guard(v_g))
    acc = jnp.sum(coeff[:, None] * g, axis=0)    # Eq. 7 signal, a cancelled
    w = jnp.sum(coeff)
    return acc - w * m_g + sqrt_vg / a * z + m_g  # Eq. 8
