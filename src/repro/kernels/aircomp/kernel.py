"""Fused AirComp aggregation — Pallas TPU kernel.

The paper's per-parameter hot loop (Eqs. 5→8) touches every gradient element
five times when written naively (normalize, transmit-scale, superpose,
denoise, denormalize). The fused kernel makes ONE pass over HBM:

    ŷ[d] = Σ_i coeff_i·g_i[d] − W·M_g + (sqrt(V_g)/a)·z[d] + M_g,
    W = Σ_i coeff_i

(the algebraic collapse of Eq. 5 normalize → Lemma-1 transmit scale →
Eq. 7 superpose → Eq. 8 denoise/denormalize), computed tile-by-tile with the
(n_devices, TILE_D) gradient block resident in VMEM. VPU-bound (no MXU): the
roofline term is HBM bytes, so the single-pass fusion is the whole win —
~5× fewer HBM touches than the composed elementwise chain.

TPU layout notes:
  * TILE_D is a multiple of 128 (lane dimension).
  * n_devices (≤ a few hundred in FL) sits in the sublane dimension; the
    device reduction is a VPU cross-sublane sum.
  * scalars (M_g, V_g, a, W) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import eps_guard

DEFAULT_TILE_D = 512

# TPU lane width: tiles stay multiples of this when clamping
_LANE = 128


def _clamp_tile(d: int, tile_d: int) -> int:
    """Clamp an oversized tile down toward D (rounded up to the 128-lane
    multiple) so a small D — e.g. a shard-local block under the lattice's
    2-D (cells × model) mesh — pads to one snug tile instead of a mostly
    dead ``tile_d``-wide grid. A caller-requested tile smaller than the
    aligned D passes through untouched (tests drive tiny tiles on purpose).
    """
    aligned = -(-d // _LANE) * _LANE
    return min(tile_d, aligned)


def _aircomp_kernel(scalars_ref, coeff_ref, g_ref, z_ref, out_ref):
    m_g = scalars_ref[0]
    v_g = scalars_ref[1]
    a = scalars_ref[2]
    w = scalars_ref[3]  # Σ_i coeff_i

    g = g_ref[...].astype(jnp.float32)          # (N, T)
    z = z_ref[...].astype(jnp.float32)          # (1, T)
    coeff = coeff_ref[...].astype(jnp.float32)  # (N, 1)

    sqrt_vg = jax.lax.sqrt(eps_guard(v_g))
    acc = jnp.sum(coeff * g, axis=0, keepdims=True)  # (1, T)
    out_ref[...] = (acc - w * m_g + (sqrt_vg / a) * z + m_g).astype(out_ref.dtype)


def _aircomp_batch_kernel(scalars_ref, coeff_ref, g_ref, z_ref, out_ref):
    b = pl.program_id(0)
    m_g = scalars_ref[b, 0]
    v_g = scalars_ref[b, 1]
    a = scalars_ref[b, 2]
    w = scalars_ref[b, 3]  # Σ_i coeff_i for this trial

    g = g_ref[0].astype(jnp.float32)            # (N, T)
    z = z_ref[0].astype(jnp.float32)            # (1, T)
    coeff = coeff_ref[0].astype(jnp.float32)    # (N, 1)

    sqrt_vg = jax.lax.sqrt(eps_guard(v_g))
    acc = jnp.sum(coeff * g, axis=0, keepdims=True)  # (1, T)
    out_ref[0] = (acc - w * m_g + (sqrt_vg / a) * z + m_g).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def aircomp_fused_batch(
    g: jnp.ndarray,       # (n_trials, n_devices, D)
    coeff: jnp.ndarray,   # (n_trials, n_devices)  mask_i · ρ_i per trial
    m_g: jnp.ndarray,     # (n_trials,)
    v_g: jnp.ndarray,     # (n_trials,)
    a: jnp.ndarray,       # (n_trials,)
    z: jnp.ndarray,       # (n_trials, D)
    *,
    tile_d: int = DEFAULT_TILE_D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Trial-batched fused Eq. 5→8 aggregation — one kernel launch serves a
    whole lattice batch (e.g. every cell of a ``repro.sim`` lattice sharing a
    policy). Returns ŷ of shape (n_trials, D).

    Grid is (n_trials, D/tile_d): the trial axis rides the outer grid
    dimension so each (N, TILE_D) gradient block is loaded from HBM exactly
    once, same as the single-trial kernel; per-trial scalars sit in SMEM and
    are indexed by the grid position.
    """
    bt, n, d = g.shape
    tile_d = _clamp_tile(d, tile_d)
    d_pad = ((d + tile_d - 1) // tile_d) * tile_d
    if d_pad != d:
        g = jnp.pad(g, ((0, 0), (0, 0), (0, d_pad - d)))
        z = jnp.pad(z, ((0, 0), (0, d_pad - d)))

    scalars = jnp.stack(
        [m_g.astype(jnp.float32), v_g.astype(jnp.float32),
         a.astype(jnp.float32), jnp.sum(coeff, axis=-1).astype(jnp.float32)],
        axis=-1,
    )  # (n_trials, 4)

    out = pl.pallas_call(
        _aircomp_batch_kernel,
        grid=(bt, d_pad // tile_d),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),              # scalars (B, 4)
            pl.BlockSpec((1, n, 1), lambda b, i: (b, 0, 0)),    # coeff column
            pl.BlockSpec((1, n, tile_d), lambda b, i: (b, 0, i)),  # grad tile
            pl.BlockSpec((1, 1, tile_d), lambda b, i: (b, 0, i)),  # noise tile
        ],
        out_specs=pl.BlockSpec((1, 1, tile_d), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((bt, 1, d_pad), g.dtype),
        interpret=interpret,
    )(scalars, coeff[:, :, None], g, z[:, None, :])
    return out[:, 0, :d]


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def aircomp_fused(
    g: jnp.ndarray,       # (n_devices, D)
    coeff: jnp.ndarray,   # (n_devices,)  mask_i · ρ_i
    m_g: jnp.ndarray,     # scalar
    v_g: jnp.ndarray,     # scalar
    a: jnp.ndarray,       # scalar
    z: jnp.ndarray,       # (D,)
    *,
    tile_d: int = DEFAULT_TILE_D,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused Eq. 5→8 aggregation. Returns ŷ of shape (D,).

    D is padded to a multiple of ``tile_d`` internally; a ``tile_d`` wider
    than (128-lane-aligned) D is clamped first, so shard-local blocks of a
    model-sharded lattice launch a snug grid rather than padding to the
    default tile.
    """
    n, d = g.shape
    tile_d = _clamp_tile(d, tile_d)
    d_pad = ((d + tile_d - 1) // tile_d) * tile_d
    if d_pad != d:
        g = jnp.pad(g, ((0, 0), (0, d_pad - d)))
        z = jnp.pad(z, (0, d_pad - d))

    scalars = jnp.stack(
        [m_g.astype(jnp.float32), v_g.astype(jnp.float32),
         a.astype(jnp.float32), jnp.sum(coeff).astype(jnp.float32)]
    )

    out = pl.pallas_call(
        _aircomp_kernel,
        grid=(d_pad // tile_d,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # scalars (4,)
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # coeff column
            pl.BlockSpec((n, tile_d), lambda i: (0, i)),  # gradient tile
            pl.BlockSpec((1, tile_d), lambda i: (0, i)),  # noise tile
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), g.dtype),
        interpret=interpret,
    )(scalars, coeff[:, None], g, z[None, :])
    return out[0, :d]
