"""Public op: fused AirComp aggregation with automatic backend dispatch.

``use_pallas='auto'`` runs the Pallas kernel on TPU, the pure-jnp reference
on CPU (interpret-mode execution is for tests, not production CPU use).

Two entry points: :func:`aircomp_aggregate_fused` for a single round and
:func:`aircomp_aggregate_fused_batch` for a trial-batched lattice round
(leading ``n_trials`` axis on every argument — the shape ``repro.sim``'s
vmapped lattice produces per policy).
"""
from __future__ import annotations

import jax

from repro.kernels.aircomp.kernel import (
    DEFAULT_TILE_D,
    aircomp_fused,
    aircomp_fused_batch,
)
from repro.kernels.aircomp.ref import aircomp_fused_batch_ref, aircomp_fused_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def aircomp_aggregate_fused(
    g, coeff, m_g, v_g, a, z, *, use_pallas: str | bool = "auto", tile_d: int = DEFAULT_TILE_D
):
    """Fused Eq. 5→8: ŷ = Σ_i coeff_i·(g_i − M_g) + sqrt(V_g)/a·z + M_g."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        return aircomp_fused(g, coeff, m_g, v_g, a, z, tile_d=tile_d)
    return aircomp_fused_ref(g, coeff, m_g, v_g, a, z)


def aircomp_aggregate_fused_batch(
    g, coeff, m_g, v_g, a, z, *, use_pallas: str | bool = "auto", tile_d: int = DEFAULT_TILE_D
):
    """Trial-batched fused Eq. 5→8 over (n_trials, n_devices, D) gradients."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        return aircomp_fused_batch(g, coeff, m_g, v_g, a, z, tile_d=tile_d)
    return aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z)


__all__ = [
    "aircomp_aggregate_fused",
    "aircomp_aggregate_fused_batch",
    "aircomp_fused",
    "aircomp_fused_batch",
    "aircomp_fused_batch_ref",
    "aircomp_fused_ref",
]
