"""Public op: fused AirComp aggregation with automatic backend dispatch.

``use_pallas`` values:

  * ``'auto'``      — the Pallas kernel on TPU, the pure-jnp reference on
    CPU; setting the ``REPRO_PALLAS_INTERPRET=1`` env var forces interpret
    mode instead (the CPU parity path for the engine's ``pallas_fused``
    aggregation backend). The var is read at TRACE time: set it before
    building engines/jits — already-compiled traces keep their mode
    (``sim.engine.cached_engine`` keys on it, so cached engines are safe;
    hand-built ``SimEngine``/lattice jits are not).
  * ``True``        — the Pallas kernel (compiled).
  * ``'interpret'`` — the Pallas kernel in interpret mode (runs anywhere;
    slow — tests/parity only).
  * ``False``       — the pure-jnp reference.

Two entry points: :func:`aircomp_aggregate_fused` for a single round and
:func:`aircomp_aggregate_fused_batch` for a trial-batched lattice round
(leading ``n_trials`` axis on every argument — the shape ``repro.sim``'s
vmapped lattice produces per policy).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.aircomp.kernel import (
    DEFAULT_TILE_D,
    aircomp_fused,
    aircomp_fused_batch,
)
from repro.kernels.aircomp.ref import aircomp_fused_batch_ref, aircomp_fused_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: str | bool) -> str | bool:
    """Normalize a ``use_pallas`` argument to True / False / 'interpret'."""
    if use_pallas == "auto":
        if os.environ.get("REPRO_PALLAS_INTERPRET"):
            return "interpret"
        return _on_tpu()
    return use_pallas


def aircomp_aggregate_fused(
    g, coeff, m_g, v_g, a, z, *, use_pallas: str | bool = "auto", tile_d: int = DEFAULT_TILE_D
):
    """Fused Eq. 5→8: ŷ = Σ_i coeff_i·(g_i − M_g) + sqrt(V_g)/a·z + M_g."""
    mode = _resolve(use_pallas)
    if mode == "interpret":
        return aircomp_fused(g, coeff, m_g, v_g, a, z, tile_d=tile_d, interpret=True)
    if mode:
        return aircomp_fused(g, coeff, m_g, v_g, a, z, tile_d=tile_d)
    return aircomp_fused_ref(g, coeff, m_g, v_g, a, z)


def aircomp_aggregate_fused_batch(
    g, coeff, m_g, v_g, a, z, *, use_pallas: str | bool = "auto", tile_d: int = DEFAULT_TILE_D
):
    """Trial-batched fused Eq. 5→8 over (n_trials, n_devices, D) gradients."""
    mode = _resolve(use_pallas)
    if mode == "interpret":
        return aircomp_fused_batch(g, coeff, m_g, v_g, a, z, tile_d=tile_d, interpret=True)
    if mode:
        return aircomp_fused_batch(g, coeff, m_g, v_g, a, z, tile_d=tile_d)
    return aircomp_fused_batch_ref(g, coeff, m_g, v_g, a, z)


__all__ = [
    "aircomp_aggregate_fused",
    "aircomp_aggregate_fused_batch",
    "aircomp_fused",
    "aircomp_fused_batch",
    "aircomp_fused_batch_ref",
    "aircomp_fused_ref",
]
