"""Pure-jnp oracles for the Mamba2 SSD kernel.

``ssd_naive`` materializes the full sequential recurrence — the ground truth:

    S_t = exp(la_t)·S_{t-1} + B_t ⊗ x_t     (state: (h, n, p))
    y_t = C_t · S_t

``ssd_chunked_ref`` re-exports the chunked jnp implementation from
models/layers.py (itself validated against ``ssd_naive``); the Pallas kernel
is checked against both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ssd_chunked as ssd_chunked_ref  # noqa: F401


def ssd_naive(xdt, la, B, C):
    """Sequential recurrence oracle.

    Args:
      xdt: (b, s, h, p) dt-scaled inputs
      la:  (b, s, h)    log decay (≤ 0)
      B:   (b, s, n)    input projection (shared across heads)
      C:   (b, s, n)    output projection
    Returns y: (b, s, h, p)
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, la_t, b_t, c_t = inp
        # state: (b, h, n, p)
        state = jnp.exp(la_t)[..., None, None] * state + jnp.einsum(
            "bn,bhp->bhnp", b_t, x_t
        )
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((b, h, n, p), xdt.dtype)
    _, ys = jax.lax.scan(
        step,
        init,
        (
            jnp.moveaxis(xdt, 1, 0),
            jnp.moveaxis(la, 1, 0),
            jnp.moveaxis(B, 1, 0),
            jnp.moveaxis(C, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1)
