"""Public op: chunked SSD scan with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_naive


def ssd(xdt, la, B, C, *, chunk: int = 256, use_pallas: str | bool = "auto"):
    """y = SSD(xdt, la, B, C). Pallas on TPU, chunked jnp elsewhere."""
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ssd_pallas(xdt, la, B, C, chunk=chunk)
    return ssd_chunked_ref(xdt, la, B, C, chunk)


__all__ = ["ssd", "ssd_pallas", "ssd_chunked_ref", "ssd_naive"]
