from repro.kernels.ssd.ops import ssd, ssd_chunked_ref, ssd_naive, ssd_pallas

__all__ = ["ssd", "ssd_pallas", "ssd_chunked_ref", "ssd_naive"]
