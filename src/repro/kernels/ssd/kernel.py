"""Mamba2 SSD chunked scan — Pallas TPU kernel (arXiv:2405.21060).

State-space duality splits the linear recurrence into:
  * intra-chunk: a (q × q) masked-decay "attention" — MXU matmuls;
  * inter-chunk: an exponential-decay state recurrence carried ACROSS grid
    steps in a VMEM scratch accumulator (the TPU grid is executed
    sequentially, which is exactly the dependency order we need).

Grid: (batch, n_chunks) — chunks innermost so the state scratch carries the
recurrence; the batch dimension resets it at chunk 0.

Block shapes (per grid step, all VMEM):
  xdt (1, q, h, p) · la (1, q, h) · B/C (1, q, n) · state scratch (h, n, p)

MXU alignment: q (chunk) is a multiple of 128 in production (256 default);
h·p and n are multiples of 128 for the einsums that hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)  # (q, h, p)
    la = la_ref[0].astype(jnp.float32)    # (q, h)
    B = b_ref[0].astype(jnp.float32)      # (q, n)
    C = c_ref[0].astype(jnp.float32)      # (q, n)
    q = xdt.shape[0]

    La = jnp.cumsum(la, axis=0)  # (q, h) inclusive cumulative log decay

    # ---- intra-chunk: masked-decay attention (MXU) ----------------------
    G = C @ B.T  # (q, q)
    diff = La[:, None, :] - La[None, :, :]  # (q, k, h)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
    M = G[:, :, None] * jnp.exp(diff)  # (q, k, h)
    y_intra = jnp.einsum("qkh,khp->qhp", M, xdt)

    # ---- inter-chunk: contribution of the carried state ------------------
    state = state_ref[...].astype(jnp.float32)  # (h, n, p)
    y_inter = jnp.einsum("qn,hnp,qh->qhp", C, state, jnp.exp(La))

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update: S ← exp(La_q)·S + Σ_t exp(La_q − La_t)·B_t ⊗ x_t --
    seg = jnp.exp(La[-1:, :] - La)  # (q, h) decay from t to chunk end
    new_contrib = jnp.einsum("qh,qn,qhp->hnp", seg, B, xdt)
    chunk_decay = jnp.exp(La[-1])[:, None, None]  # (h, 1, 1)
    state_ref[...] = (chunk_decay * state + new_contrib).astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    xdt: jnp.ndarray,  # (b, s, h, p)
    la: jnp.ndarray,   # (b, s, h)
    B: jnp.ndarray,    # (b, s, n)
    C: jnp.ndarray,    # (b, s, n)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunked SSD scan. Returns y: (b, s, h, p). Requires s % chunk == 0."""
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    return pl.pallas_call(
        _ssd_kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((h, n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, la, B, C)
