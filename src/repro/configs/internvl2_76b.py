"""internvl2-76b — InternViT + Llama-3-70B-style LLM backbone [arXiv:2404.16821].

The assignment covers the language backbone: 80 layers, d_model=8192, GQA
kv=8, vocab=128256. The InternViT vision encoder + MLP projector is a stub:
input_specs() supplies precomputed patch embeddings (B, 256, d_model).
"""
from repro.models.config import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        arch_type="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500000.0,
        vlm=VLMConfig(n_patches=256),
        source="arXiv:2404.16821",
    )
