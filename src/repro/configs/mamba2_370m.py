"""mamba2-370m — attention-free SSD state-space model [arXiv:2405.21060].

48 layers, d_model=1024, d_state=128, head_dim=64 (d_inner=2048, 32 heads).
"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        arch_type="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=16,   # unused (attention-free); kept for config uniformity
        n_kv_heads=16,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128),
        source="arXiv:2405.21060",
    )
