"""seamless-m4t-large-v2 — speech enc / text dec [arXiv:2308.11596].

24 encoder + 24 decoder layers, d_model=1024, vocab=256206 (padded to 256256
for 16-way sharding). The conformer speech frontend is a stub: input_specs()
supplies precomputed frame embeddings (B, 1024, d_model).
"""
from repro.models.config import EncDecConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        arch_type="encdec",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        encdec=EncDecConfig(n_enc_layers=24, n_enc_frames=1024),
        source="arXiv:2308.11596",
    )
