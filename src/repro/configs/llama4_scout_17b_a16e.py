"""llama4-scout-17b-a16e — 16-expert top-1 MoE with a shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]. Early-fusion multimodality is out of
the assigned backbone scope (text tokens only here)."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=500000.0,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=1),
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
