"""zamba2-2.7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, one *shared* (weight-tied) attention+MLP
block invoked every 6 layers (simplification of Zamba2's shared-block-with-
LoRA design; the sharing pattern and cost structure are preserved).
"""
from repro.models.config import HybridConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,  # MHA in the shared block
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64),
        hybrid=HybridConfig(attn_every=6),
        source="arXiv:2411.15242",
    )
