"""Architecture registry: the 10 assigned architectures × 4 input shapes.

Public API:
  ARCH_IDS                      — the assigned architecture identifiers
  get_config(arch_id, shape)    — full-size config (shape-aware: long_500k
                                  swaps in the sliding-window variant)
  reduced_config(arch_id)       — CPU-smoke-sized variant of the same family
  supports_shape(arch_id, shape)— long_500k/decode applicability (DESIGN §4)
  input_specs(cfg, shape)       — ShapeDtypeStruct stand-ins for every model
                                  input of the (train|prefill|decode) step
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.cache import init_cache
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = (
    "zamba2-2.7b",
    "olmoe-1b-7b",
    "internvl2-76b",
    "qwen2-0.5b",
    "mistral-large-123b",
    "llama4-scout-17b-a16e",
    "seamless-m4t-large-v2",
    "qwen2.5-14b",
    "phi4-mini-3.8b",
    "mamba2-370m",
)

_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "internvl2-76b": "internvl2_76b",
    "qwen2-0.5b": "qwen2_0p5b",
    "mistral-large-123b": "mistral_large_123b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2.5-14b": "qwen2p5_14b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "mamba2-370m": "mamba2_370m",
}

# Archs whose long_500k decode runs via a documented sliding-window variant
# (W=8192 ring-buffer cache). Pure full-attention archs with no variant are
# skipped for long_500k (recorded in DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192
LONG_CONTEXT_VIA_WINDOW = (
    "olmoe-1b-7b",
    "qwen2-0.5b",
    "llama4-scout-17b-a16e",
    "phi4-mini-3.8b",
)
LONG_CONTEXT_SKIP = (
    "internvl2-76b",
    "mistral-large-123b",
    "qwen2.5-14b",
    "seamless-m4t-large-v2",
)


def base_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_config(arch_id: str, shape: InputShape | str | None = None) -> ModelConfig:
    """Full-size config for ``arch_id``; long_500k selects the sliding-window
    variant for the dense/MoE archs that support it."""
    cfg = base_config(arch_id)
    if shape is None:
        return cfg
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    if shape.name == "long_500k":
        if arch_id in LONG_CONTEXT_SKIP:
            raise ValueError(
                f"{arch_id} is pure full-attention — long_500k is skipped "
                "(DESIGN.md §4 Arch-applicability)"
            )
        if arch_id in LONG_CONTEXT_VIA_WINDOW:
            cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def supports_shape(arch_id: str, shape: InputShape | str) -> bool:
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    if shape.name == "long_500k":
        return arch_id not in LONG_CONTEXT_SKIP
    return True


def reduced_config(arch_id: str) -> ModelConfig:
    """Smoke variant of the same family: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    cfg = base_config(arch_id)
    updates = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=128
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk_size=16
        )
    if cfg.hybrid is not None:
        updates["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=2)
    if cfg.encdec is not None:
        updates["encdec"] = dataclasses.replace(
            cfg.encdec, n_enc_layers=2, n_enc_frames=16
        )
    if cfg.vlm is not None:
        updates["vlm"] = dataclasses.replace(cfg.vlm, n_patches=8)
    return dataclasses.replace(cfg, **updates)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no device allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    cfg: ModelConfig,
    shape: InputShape | str,
    dtype=jnp.bfloat16,
) -> dict:
    """ShapeDtypeStructs for every input of the step the shape exercises.

    train/prefill → {"batch": {tokens, [embeds|frames]}}
    decode        → {"token", "cache", "t"}  (cache sized to shape.seq_len)
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len

    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.arch_type == "vlm":
            n_p = cfg.vlm.n_patches
            batch["tokens"] = _sds((b, s - n_p), jnp.int32)
            batch["embeds"] = _sds((b, n_p, cfg.d_model), dtype)
        elif cfg.arch_type == "encdec":
            batch["tokens"] = _sds((b, s), jnp.int32)
            batch["frames"] = _sds((b, cfg.encdec.n_enc_frames, cfg.d_model), dtype)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        return {"batch": batch}

    # decode: one token against a cache covering the context
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, dtype))
    return {
        "token": _sds((b, 1), jnp.int32),
        "cache": cache,
        "t": _sds((), jnp.int32),
    }


__all__ = [
    "ARCH_IDS",
    "LONG_CONTEXT_SKIP",
    "LONG_CONTEXT_VIA_WINDOW",
    "base_config",
    "get_config",
    "input_specs",
    "reduced_config",
    "supports_shape",
]
