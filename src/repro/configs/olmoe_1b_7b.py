"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060].

16 layers, d_model=2048, per-expert d_ff=1024 (1B active / 7B total).
"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        arch_type="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
        source="arXiv:2409.02060",
    )
