"""mistral-large-123b — dense GQA [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        arch_type="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=32768,
        rope_theta=1000000.0,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
