"""Fault-tolerant lattice sweeps: checkpoint/resume + deterministic faults.

The lattice engine's ``lax.scan`` carry (:class:`~repro.sim.engine.SimState`
— params, PRNG chain, channel-process state, ``AlgState``) already holds
EVERYTHING that evolves across rounds, so a sweep can be segmented into
``checkpoint_every``-round chunks whose carry is persisted between chunks
and re-entered after a crash. This module is that re-entry contract:

  * :func:`run_lattice_checkpointed` — ``run_lattice``'s policy-fused path,
    chunked: one batched-carry ``init`` program + ONE fixed-length ``chunk``
    program (the final short chunk is padded with the engine's
    carry-preserving ``active``-mask no-ops, so every chunk dispatches the
    same AOT executable). After each chunk the full carry + the records so
    far are written through ``repro.checkpoint``'s crash-atomic npz saver.
    HARD GUARANTEE: a sweep interrupted at any checkpoint boundary and
    resumed produces bit-identical records to the uninterrupted (chunked)
    run — the chunks are the same executable over the same carries, and the
    npz round-trip is bytewise on every leaf (PRNG keys included).
  * worker sharding — :func:`run_worker_shard` runs one contiguous slice of
    the fused flat cell grid (per-rank checkpoints, per-rank shard npz) and
    :func:`merge_shards` reassembles the full :class:`LatticeRecords`; the
    supervised launcher (``repro.launch.distributed``) restarts a crashed
    rank and it resumes from ITS last checkpoint. Workers are independent
    single-host processes (no collectives), so one rank's death never
    wedges the cohort.
  * deterministic fault injection — the ``REPRO_FAULT_*`` env contract:

        REPRO_FAULT_KILL=<rank>:<round>   worker <rank> hard-exits (code
                                          113) at the first checkpoint
                                          boundary after <round>
        REPRO_FAULT_NAN=<cell>:<round>    flat-fused cell <cell>'s aggregate
                                          ŷ is poisoned to NaN at exactly
                                          round <round> (an input VALUE to
                                          the chunk program — unfaulted
                                          cells share the same executable
                                          and are bitwise unchanged)

    Faults are one-shot by design: the supervisor strips ``REPRO_FAULT_*``
    from a restarted rank's environment, so an injected kill is recovered
    instead of re-fired. NaN faults compose with
    ``POFLConfig.on_nonfinite="skip"`` (the in-trace quarantine): the
    poisoned round holds params/AlgState and is counted on the records'
    ``health`` subtree.

Checkpoint layout (all writes crash-atomic, npz is the commit point):

    <dir>/ckpt-<t_next:06d>.npz        {"state": SimState, "records": ...}
    <dir>/ckpt-<t_next:06d>.meta.json  {"t_next", "fingerprint", ...}

Discovery keys on npz presence (the atomic saver publishes the sidecar
FIRST), and the fingerprint — spec + config + cell slice — refuses to
resume a checkpoint written by a different sweep.
"""
from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core.channel import ChannelConfig
from repro.core.metrics import RoundDiagnostics, RoundHealth
from repro.core.pofl import DeviceData, POFLConfig
from repro.obs.config import ObsConfig
from repro.obs.sink import emit, process_coords
from repro.obs.spans import span
from repro.sim.engine import (
    _RECORD_SCALARS,
    FUSED_ALGORITHM,
    FUSED_POLICY,
    RoundRecord,
    cached_engine,
)
from repro.sim.lattice import (
    LatticeRecords,
    LatticeSpec,
    assemble_flat_fused,
    fused_flat_grid,
)
from repro.sim.tasks import EvalRecord

# -- the REPRO_FAULT_* env contract ----------------------------------------

ENV_FAULT_KILL = "REPRO_FAULT_KILL"  # "<rank>:<round>"
ENV_FAULT_NAN = "REPRO_FAULT_NAN"    # "<flat fused cell>:<round>"
FAULT_ENV_VARS = (ENV_FAULT_KILL, ENV_FAULT_NAN)
# distinctive exit code of an injected kill (distinguishable from a real
# crash in the supervisor's logs; any nonzero code triggers the same restart)
FAULT_EXIT_CODE = 113

_CKPT_RE = re.compile(r"ckpt-(\d+)\.npz$")


def _parse_fault(name: str) -> tuple[int, int] | None:
    """Parse one ``<int>:<int>`` fault env var; None when unset/malformed
    (a malformed value raises — a silently ignored fault would make a CI
    fault-injection job vacuously green)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        a, b = raw.split(":")
        return int(a), int(b)
    except ValueError as e:
        raise ValueError(
            f"{name} must be '<int>:<int>', got {raw!r}"
        ) from e


def fault_kill() -> tuple[int, int] | None:
    """The ``REPRO_FAULT_KILL`` (rank, round) injection point, or None."""
    return _parse_fault(ENV_FAULT_KILL)


def fault_nan() -> tuple[int, int] | None:
    """The ``REPRO_FAULT_NAN`` (flat cell, round) injection point, or None."""
    return _parse_fault(ENV_FAULT_NAN)


def fault_nan_rounds(lo: int, hi: int) -> np.ndarray:
    """The per-cell NaN-injection rounds for the ``[lo, hi)`` slice of the
    fused flat grid: all ``-1`` (never) unless ``REPRO_FAULT_NAN`` names a
    cell inside the slice. An input VALUE to the chunk program — the
    no-fault array runs the identical executable."""
    fault = np.full(hi - lo, -1, np.int32)
    nan_point = fault_nan()
    if nan_point is not None and lo <= nan_point[0] < hi:
        fault[nan_point[0] - lo] = nan_point[1]
    return fault


def _maybe_fault_kill(t_next: int, rank: int) -> None:
    """Hard-exit (``os._exit(113)``) when ``REPRO_FAULT_KILL`` names this
    rank and the sweep has passed the injected round. Called AFTER the
    checkpoint for ``t_next`` is committed, so the kill point is exactly a
    checkpoint boundary — recovery is deterministic and loses nothing."""
    kill = fault_kill()
    if kill is None or kill[0] != rank or t_next <= kill[1]:
        return
    emit(
        "fault", "resilience.fault_kill",
        rank=rank, round=kill[1], t_next=t_next, exit_code=FAULT_EXIT_CODE,
    )
    os._exit(FAULT_EXIT_CODE)


# -- checkpoint plumbing ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Where/how often a chunked sweep persists its carry.

    ``every`` is the chunk length in rounds (the scan is segmented into
    ``ceil(T / every)`` dispatches of ONE fixed-length executable); ``keep``
    bounds how many recent checkpoints stay on disk (older ones are pruned
    after each successful save — never the one just written)."""

    dir: str
    every: int
    keep: int = 2

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {self.every}")


def _ckpt_path(ckpt_dir: str, t_next: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt-{t_next:06d}.npz")


def latest_checkpoint(ckpt_dir: str) -> tuple[int, str] | None:
    """The most advanced published checkpoint under ``ckpt_dir`` as
    ``(t_next, npz_path)``, or None. Keys on npz presence only — the
    crash-atomic saver guarantees a visible npz is complete and its
    ``.meta.json`` sidecar was published first."""
    best: tuple[int, str] | None = None
    for path in glob.glob(os.path.join(ckpt_dir, "ckpt-*.npz")):
        m = _CKPT_RE.search(path)
        if m is None:
            continue
        t = int(m.group(1))
        if best is None or t > best[0]:
            best = (t, path)
    return best


def _prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    found = sorted(
        (int(_CKPT_RE.search(p).group(1)), p)
        for p in glob.glob(os.path.join(ckpt_dir, "ckpt-*.npz"))
        if _CKPT_RE.search(p)
    )
    for t, path in found[:-keep] if keep > 0 else []:
        for stale in (path, _ckpt_path(ckpt_dir, t)[:-4] + ".meta.json"):
            if os.path.exists(stale):
                os.remove(stale)


def _fingerprint(
    spec: LatticeSpec, cfg: POFLConfig, scenario: str,
    scenario_params: dict | None, cell_range: tuple[int, int],
) -> str:
    """Identity of one sweep's checkpoint stream: resuming under a different
    spec/config/slice must fail loudly, not deserialize garbage."""
    payload = repr((
        spec, dataclasses.replace(cfg, seed=0), scenario,
        sorted((scenario_params or {}).items()), cell_range,
    ))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _records_from_npz(z, prefix: str = "records/") -> RoundRecord:
    """Rebuild the host-side flat record pytree from its '/'-joined npz keys
    (the inverse of ``save_pytree``'s flattening for this known structure —
    optional subtrees are present iff their keys are)."""
    kw = {f: z[f"{prefix}{f}"] for f in _RECORD_SCALARS}
    diag = None
    if f"{prefix}diag/{RoundDiagnostics._fields[0]}" in z.files:
        diag = RoundDiagnostics(
            *(z[f"{prefix}diag/{f}"] for f in RoundDiagnostics._fields)
        )
    ev = None
    if f"{prefix}eval/{EvalRecord._fields[0]}" in z.files:
        ev = EvalRecord(
            *(z[f"{prefix}eval/{f}"] for f in EvalRecord._fields)
        )
    health = None
    if f"{prefix}health/{RoundHealth._fields[0]}" in z.files:
        health = RoundHealth(
            *(z[f"{prefix}health/{f}"] for f in RoundHealth._fields)
        )
    return RoundRecord(diag=diag, eval=ev, health=health, **kw)


def _concat_records(parts: list[RoundRecord]) -> RoundRecord:
    """Concatenate per-chunk record pytrees along the round axis (leaves are
    (b, t_chunk) host arrays)."""
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(
        lambda *xs: np.concatenate(xs, axis=1), *parts
    )


def _eval_schedule(spec: LatticeSpec, has_eval: bool):
    """``run_lattice``'s exact eval schedule: every ``eval_every`` rounds
    plus the final round (nothing when there is no eval_fn)."""
    t_ints = np.arange(spec.n_rounds, dtype=np.int32)
    if has_eval and spec.n_rounds:
        do_eval = (t_ints % spec.eval_every == 0) | (t_ints == spec.n_rounds - 1)
    else:
        do_eval = np.zeros(spec.n_rounds, bool)
    return do_eval, t_ints[do_eval]


# -- the chunked runner ----------------------------------------------------


def _run_cells_checkpointed(
    loss_fn: Callable,
    data: DeviceData,
    params0,
    spec: LatticeSpec,
    base_cfg: POFLConfig | None = None,
    eval_fn: Callable | None = None,
    channel_cfg: ChannelConfig | None = None,
    scenario: str = "static_rayleigh",
    scenario_params: dict | None = None,
    obs: ObsConfig | None = None,
    checkpoint: CheckpointConfig | None = None,
    resume: bool = True,
    cell_range: tuple[int, int] | None = None,
    stop_after_round: int | None = None,
) -> RoundRecord | None:
    """The core chunked loop over the ``[lo, hi)`` slice of the fused flat
    cell grid → host-side flat records ((b, T) leaves), or None when
    ``stop_after_round`` simulated an interruption (tests/harness only;
    the checkpoint for every completed chunk is already on disk)."""
    base_cfg = base_cfg or POFLConfig(n_devices=data.n_devices)
    algs = tuple(spec.algorithms)
    if not algs:
        raise ValueError("spec.algorithms must name at least one algorithm")
    traced_algs = len(algs) > 1
    cfg = dataclasses.replace(
        base_cfg,
        policy=FUSED_POLICY,
        local_algorithm=FUSED_ALGORITHM if traced_algs else algs[0],
        n_devices=data.n_devices,
    )
    noise, alpha, seed, policy, alg = fused_flat_grid(spec)
    lo, hi = cell_range if cell_range is not None else (0, noise.size)
    if not (0 <= lo < hi <= noise.size):
        raise ValueError(
            f"cell_range {cell_range} outside the {noise.size}-cell grid"
        )
    rank = process_coords()[0]
    fingerprint = _fingerprint(spec, cfg, scenario, scenario_params, (lo, hi))

    engine = cached_engine(
        loss_fn, data, cfg,
        channel_cfg=channel_cfg, scenario=scenario,
        scenario_params=scenario_params, eval_fn=eval_fn, obs=obs,
    )
    noise_b = jnp.asarray(noise[lo:hi])
    alpha_b = jnp.asarray(alpha[lo:hi])
    seed_b = jnp.asarray(seed[lo:hi])
    policy_b = jnp.asarray(policy[lo:hi])
    algorithm_b = jnp.asarray(alg[lo:hi]) if traced_algs else None
    fault_b = jnp.asarray(fault_nan_rounds(lo, hi))

    T = spec.n_rounds
    do_eval_global, _ = _eval_schedule(spec, eval_fn is not None)
    chunk = checkpoint.every if checkpoint is not None else max(T, 1)

    # the batched initial carry — also the structure/sharding template a
    # persisted carry is restored into (stable executable signature on resume)
    state_b = engine.init_lattice_states(
        params0, seed_b, fused_algorithms=traced_algs
    )
    t_next = 0
    rec_parts: list[RoundRecord] = []

    if checkpoint is not None and resume:
        found = latest_checkpoint(checkpoint.dir)
        if found is not None:
            ck_t, ck_path = found
            meta_path = ck_path[:-4] + ".meta.json"
            with open(meta_path) as f:
                meta = json.load(f)
            if meta.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"checkpoint {ck_path} was written by a different sweep "
                    f"(fingerprint {meta.get('fingerprint')!r} != "
                    f"{fingerprint!r}); refusing to resume"
                )
            state_b = load_pytree(ck_path, {"state": state_b})["state"]
            with np.load(ck_path) as z:
                rec_parts = [_records_from_npz(z)]
            t_next = int(meta["t_next"])
            emit(
                "checkpoint", "resilience.resume",
                path=ck_path, t_next=t_next, rank=rank, cells=int(hi - lo),
            )

    emit(
        "heartbeat", "resilience.heartbeat",
        round=t_next, total=T, rank=rank, cells=int(hi - lo),
    )
    with span(
        "resilience.sweep", cells=int(hi - lo), n_rounds=T,
        chunk=chunk, resumed_at=t_next,
    ):
        while t_next < T:
            k = min(chunk, T - t_next)
            # pad the final short chunk to the static chunk length: inactive
            # rounds are genuine carry-preserving lax.cond no-ops, so EVERY
            # chunk dispatches the same AOT executable
            t_ints = np.arange(chunk, dtype=np.int32) + t_next
            active = np.arange(chunk) < k
            do_ev = np.zeros(chunk, bool)
            do_ev[:k] = do_eval_global[t_next:t_next + k]
            state_b, recs = engine.run_lattice_chunk(
                state_b, t_ints, do_ev, active,
                noise_b, alpha_b, policy_b,
                algorithm_b=algorithm_b, fault_b=fault_b,
            )
            recs = jax.device_get(recs)
            rec_parts.append(jax.tree.map(lambda a: a[:, :k], recs))
            t_next += k
            emit(
                "heartbeat", "resilience.heartbeat",
                round=t_next, total=T, rank=rank, cells=int(hi - lo),
            )
            if checkpoint is not None:
                flat = _concat_records(rec_parts)
                rec_parts = [flat]
                save_pytree(
                    _ckpt_path(checkpoint.dir, t_next),
                    {"state": state_b, "records": flat},
                    metadata={
                        "t_next": t_next,
                        "fingerprint": fingerprint,
                        "cells": [int(lo), int(hi)],
                        "n_rounds": T,
                        "rank": rank,
                    },
                )
                _prune_checkpoints(checkpoint.dir, checkpoint.keep)
                emit(
                    "checkpoint", "resilience.checkpoint",
                    t_next=t_next, total=T, rank=rank,
                )
                _maybe_fault_kill(t_next, rank)
            if (
                stop_after_round is not None
                and t_next >= stop_after_round
                and t_next < T
            ):
                return None  # simulated interruption (checkpoint committed)
    return _concat_records(rec_parts)


def run_lattice_checkpointed(
    loss_fn: Callable,
    data: DeviceData,
    params0,
    spec: LatticeSpec,
    base_cfg: POFLConfig | None = None,
    eval_fn: Callable | None = None,
    channel_cfg: ChannelConfig | None = None,
    scenario: str = "static_rayleigh",
    scenario_params: dict | None = None,
    obs: ObsConfig | None = None,
    checkpoint: CheckpointConfig | None = None,
    checkpoint_every: int | None = None,
    checkpoint_dir: str | None = None,
    resume: bool = True,
    _stop_after_round: int | None = None,
) -> LatticeRecords | None:
    """``run_lattice``'s policy-fused sweep, chunked + checkpointable.

    ``checkpoint`` (or the ``checkpoint_every``/``checkpoint_dir`` pair)
    segments the T-round scan into fixed-length chunks and persists the full
    carry + partial records after each; ``resume=True`` re-enters from the
    newest checkpoint in the directory (fingerprint-guarded). With
    ``checkpoint_every=None`` and no ``REPRO_FAULT_*`` env the whole sweep
    is one chunk and nothing is written — the plain fused lattice, chunked
    at T.

    Returns the full-grid :class:`LatticeRecords` (same axes/ordering as
    ``run_lattice``). Bit-identity contract: interrupted-and-resumed equals
    uninterrupted — both are the same chunk executable over the same
    carries. Chunked-vs-``run_lattice`` comparisons are CROSS-PROGRAM
    (different executables) and get the documented ≤1-ULP reduction
    tolerance instead.

    ``_stop_after_round`` (tests/harness) simulates a crash: the runner
    returns None at the first checkpoint boundary ≥ the given round, with
    that checkpoint already committed.
    """
    if checkpoint is None and checkpoint_every is not None:
        if checkpoint_dir is None:
            raise ValueError("checkpoint_every needs checkpoint_dir")
        checkpoint = CheckpointConfig(dir=checkpoint_dir, every=checkpoint_every)
    flat = _run_cells_checkpointed(
        loss_fn, data, params0, spec,
        base_cfg=base_cfg, eval_fn=eval_fn, channel_cfg=channel_cfg,
        scenario=scenario, scenario_params=scenario_params, obs=obs,
        checkpoint=checkpoint, resume=resume,
        stop_after_round=_stop_after_round,
    )
    if flat is None:
        return None
    do_eval, eval_rounds = _eval_schedule(spec, eval_fn is not None)
    return assemble_flat_fused(spec, flat, do_eval, eval_rounds)


# -- worker sharding (the supervised launcher's workload) ------------------


def shard_bounds(n_cells: int, rank: int, count: int) -> tuple[int, int]:
    """Contiguous near-equal split of the flat fused grid across ``count``
    workers (every cell owned exactly once)."""
    if not (0 <= rank < count):
        raise ValueError(f"rank {rank} outside 0..{count - 1}")
    return (rank * n_cells) // count, ((rank + 1) * n_cells) // count


def run_worker_shard(
    loss_fn: Callable,
    data: DeviceData,
    params0,
    spec: LatticeSpec,
    shard_out: str,
    ckpt_dir: str,
    checkpoint_every: int,
    rank: int | None = None,
    count: int | None = None,
    **kw: Any,
) -> tuple[int, int]:
    """Run THIS worker's slice of the sweep (rank/count default to the
    ``REPRO_DIST_*`` env contract), checkpointing under ``<ckpt_dir>/r<rank>``
    and publishing the finished flat records to ``shard_out`` (crash-atomic).
    Returns the ``(lo, hi)`` slice."""
    if rank is None or count is None:
        rank, count = process_coords()
    lo, hi = shard_bounds(spec.n_cells, rank, count)
    eval_fn = kw.get("eval_fn")
    checkpoint = CheckpointConfig(
        dir=os.path.join(ckpt_dir, f"r{rank}"), every=checkpoint_every
    )
    flat = _run_cells_checkpointed(
        loss_fn, data, params0, spec,
        checkpoint=checkpoint, cell_range=(lo, hi), **kw,
    )
    save_pytree(
        shard_out, {"records": flat},
        metadata={
            "lo": int(lo), "hi": int(hi), "rank": int(rank),
            "count": int(count), "has_eval": eval_fn is not None,
        },
    )
    emit(
        "shard", "resilience.shard_done",
        rank=rank, lo=int(lo), hi=int(hi), path=shard_out,
    )
    return lo, hi


def merge_shards(spec: LatticeSpec, shard_paths: list[str]) -> LatticeRecords:
    """Reassemble per-worker shard npzs (``run_worker_shard`` outputs) into
    the full-grid :class:`LatticeRecords`. The shards must tile the grid
    exactly — gaps or overlaps raise."""
    shards = []
    has_eval = False
    for path in shard_paths:
        with open(path[:-4] + ".meta.json" if path.endswith(".npz")
                  else path + ".meta.json") as f:
            meta = json.load(f)
        npz_path = path if path.endswith(".npz") else path + ".npz"
        with np.load(npz_path) as z:
            recs = _records_from_npz(z)
        shards.append((meta["lo"], meta["hi"], recs))
        has_eval = has_eval or bool(meta.get("has_eval"))
    shards.sort(key=lambda s: s[0])
    expect = 0
    for lo, hi, _ in shards:
        if lo != expect:
            raise ValueError(
                f"shards do not tile the grid: expected lo={expect}, got {lo}"
            )
        expect = hi
    if expect != spec.n_cells:
        raise ValueError(
            f"shards cover {expect} cells, grid has {spec.n_cells}"
        )
    flat = jax.tree.map(
        lambda *xs: np.concatenate(xs, axis=0), *(s[2] for s in shards)
    )
    do_eval, eval_rounds = _eval_schedule(spec, has_eval)
    return assemble_flat_fused(spec, flat, do_eval, eval_rounds)
