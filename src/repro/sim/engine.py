"""The scanned PO-FL round engine.

Runs Algorithm 1 (``core.pofl.round_algorithm``) under ``lax.scan`` with the
whole carry — params, PRNG key, channel-process state — resident on device,
so a T-round segment is ONE dispatch with no per-round host sync. The carry
is donated on accelerator backends (the previous round's buffers are reused
in place).

Key discipline is bit-identical to the historical per-round ``run_pofl``
Python loop (pinned by tests/test_sim.py):

    key = PRNGKey(cfg.seed)
    k_chan_init, key = split(key)           # channel process init
    per round: key, k_round = split(key)
               k_batch, k_chan, k_sched, k_noise = split(k_round, 4)

Three entry points:

  * :meth:`SimEngine.init` — build the initial :class:`SimState` (pure; the
    seed may be a traced scalar, so lattice cells vmap over it).
  * :meth:`SimEngine.scan_rounds` — the pure scanned program
    ``(state, t_ints, do_eval, noise_power, alpha) -> (state, RoundRecord)``;
    ``repro.sim.lattice`` vmaps this across cells. ``noise_power``/``alpha``
    may be traced (lattice axes); anything structural is static.
  * :meth:`SimEngine.run_with_history` — the ``run_pofl``-compatible driver:
    scan in chunks between eval rounds, evaluate with an arbitrary Python
    ``eval_fn`` on the host, return ``(params, History)``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.pofl import DeviceData, History, POFLConfig, round_algorithm
from repro.sim.scenario import make_channel_process


class SimState(NamedTuple):
    """The donated scan carry: everything that evolves across rounds."""

    params: Any       # model pytree
    key: jax.Array    # PRNG chain
    chan: Any         # channel-process state pytree


class RoundRecord(NamedTuple):
    """Per-round on-device metric record (stacked over rounds by the scan)."""

    e_com: jnp.ndarray        # Eq. 15 closed-form communication distortion
    e_var: jnp.ndarray        # realized global update variance (Thm. 1)
    grad_norm: jnp.ndarray    # ||ŷ^t||
    n_scheduled: jnp.ndarray  # realized |S^t|
    loss: jnp.ndarray         # eval loss (0 where not evaluated)
    acc: jnp.ndarray          # eval accuracy (0 where not evaluated)


def _default_channel_cfg(cfg: POFLConfig) -> ChannelConfig:
    return ChannelConfig(
        n_devices=cfg.n_devices,
        tx_power=cfg.tx_power,
        noise_power=cfg.noise_power,
    )


class SimEngine:
    """Scan-over-rounds engine for one (task, config, channel scenario).

    Args:
      loss_fn: per-device loss ``f(params, x, y)`` (jax-traceable).
      data:    stacked per-device :class:`DeviceData`.
      cfg:     :class:`POFLConfig` (policy/sampler/|S|/batch are static).
      channel_cfg: physical-layer constants; defaults to the config the
        historical ``run_pofl`` built from ``cfg``.
      scenario: channel-process name from ``sim.scenario.CHANNEL_SCENARIOS``.
      scenario_params: extra kwargs for the scenario (e.g. ``corr=0.95``).
      eval_fn: optional *traceable* ``params -> (loss, acc)`` evaluated
        inside the scan on rounds flagged by ``do_eval`` (used by the
        lattice; ``run_with_history`` instead takes an arbitrary Python
        callable and evaluates between chunks).
    """

    def __init__(
        self,
        loss_fn: Callable,
        data: DeviceData,
        cfg: POFLConfig,
        channel_cfg: ChannelConfig | None = None,
        scenario: str = "static_rayleigh",
        scenario_params: dict | None = None,
        eval_fn: Callable | None = None,
    ):
        self.loss_fn = loss_fn
        self.data = data
        self.cfg = cfg
        self.channel_cfg = channel_cfg or _default_channel_cfg(cfg)
        self.process = make_channel_process(
            scenario, self.channel_cfg, **(scenario_params or {})
        )
        self.eval_fn = eval_fn
        # Donating the carry on CPU only triggers "donation not implemented"
        # warnings; donate on accelerators where it buys in-place reuse.
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._chunk_jit = jax.jit(
            self._chunk, static_argnames=("n_steps",), donate_argnums=donate
        )
        self._donating = bool(donate)

    # -- state construction -------------------------------------------------

    def init(self, params0, seed) -> SimState:
        """Initial carry. ``seed`` may be traced (lattice vmaps over it)."""
        key = jax.random.PRNGKey(seed)
        k_chan_init, key = jax.random.split(key)
        chan = self.process.init(k_chan_init)
        return SimState(params=params0, key=key, chan=chan)

    # -- the scanned program ------------------------------------------------

    def scan_rounds(
        self,
        state: SimState,
        t_ints: jnp.ndarray,       # (T,) int32 round indices
        do_eval: jnp.ndarray,      # (T,) bool — run eval_fn this round
        noise_power=None,          # traced scalar or None → cfg.noise_power
        alpha=None,                # traced scalar or None → cfg.alpha
    ) -> tuple[SimState, RoundRecord]:
        """Pure scan over rounds; vmap-safe (xs stay unbatched, so the eval
        ``lax.cond`` remains a genuine branch, not a select)."""

        def body(st: SimState, x):
            t_int, ev = x
            t = t_int.astype(jnp.float32)
            key, k_round = jax.random.split(st.key)
            k_batch, k_chan, k_sched, k_noise = jax.random.split(k_round, 4)
            chan, h, avail = self.process.step(st.chan, k_chan)
            params, m = round_algorithm(
                self.loss_fn, self.data, self.cfg, st.params, h,
                k_batch, k_sched, k_noise, t,
                noise_power=noise_power, alpha=alpha,
                # processes that never drop skip the masking entirely →
                # bit-identical to the legacy static path
                avail=avail if self.process.can_drop else None,
            )
            if self.eval_fn is None:
                loss = acc = jnp.zeros(())
            else:
                loss, acc = jax.lax.cond(
                    ev,
                    lambda p: tuple(
                        jnp.asarray(v, jnp.float32) for v in self.eval_fn(p)
                    ),
                    lambda p: (jnp.zeros(()), jnp.zeros(())),
                    params,
                )
            rec = RoundRecord(
                e_com=m.e_com, e_var=m.e_var, grad_norm=m.grad_norm,
                n_scheduled=m.n_scheduled, loss=loss, acc=acc,
            )
            return SimState(params=params, key=key, chan=chan), rec

        return jax.lax.scan(body, state, (t_ints, do_eval))

    def _chunk(self, state: SimState, t0, n_steps: int):
        t_ints = t0 + jnp.arange(n_steps, dtype=jnp.int32)
        do_eval = jnp.zeros((n_steps,), bool)
        return self.scan_rounds(state, t_ints, do_eval)

    # -- run_pofl-compatible driver -----------------------------------------

    def run_with_history(
        self,
        params0,
        n_rounds: int,
        eval_fn: Callable | None = None,
        eval_every: int = 5,
    ) -> tuple[Any, History]:
        """Chunked scan with host-side eval between chunks → (params, History).

        ``eval_fn`` may be any Python callable (it never enters the trace);
        metrics sync to host once per chunk instead of once per round.

        Compile-cost note: distinct chunk lengths (up to three — the t=0
        eval chunk, the ``eval_every`` body, and the tail) each trace the
        scan once, so a cold single call pays ~3 scan compiles where the
        historical per-round loop paid one round-body compile; the scan wins
        at larger ``n_rounds`` (no per-round dispatch/sync) and sweeps
        should use ``sim.lattice`` (one compile per policy for ALL cells).
        Engine-level jit caching across ``run_pofl`` calls is a ROADMAP
        item.
        """
        params0 = jax.tree.map(jnp.asarray, params0)
        if self._donating:
            params0 = jax.tree.map(lambda x: jnp.array(x, copy=True), params0)
        state = self.init(params0, self.cfg.seed)

        hist = History(loss=[], e_com=[], e_var=[], test_acc=[], test_round=[])
        if eval_fn is None:
            eval_ts: list[int] = []
        else:
            eval_ts = sorted(
                {t for t in range(n_rounds) if t % eval_every == 0}
                | ({n_rounds - 1} if n_rounds else set())
            )

        t = 0
        for stop in [et + 1 for et in eval_ts] + [n_rounds]:
            if stop > t:
                state, recs = self._chunk_jit(state, t, n_steps=stop - t)
                hist.e_com.extend(np.asarray(recs.e_com).tolist())
                hist.e_var.extend(np.asarray(recs.e_var).tolist())
                t = stop
            if eval_fn is not None and t - 1 in eval_ts and t - 1 not in hist.test_round:
                loss, acc = eval_fn(state.params)
                hist.loss.append(float(loss))
                hist.test_acc.append(float(acc))
                hist.test_round.append(t - 1)
        return state.params, hist
