"""The scanned PO-FL round engine.

Runs Algorithm 1 (``core.pofl.round_algorithm``) under ``lax.scan`` with the
whole carry — params, PRNG key, channel-process state — resident on device,
so a T-round segment is ONE dispatch with no per-round host sync. The carry
is donated on accelerator backends (the previous round's buffers are reused
in place).

Key discipline is bit-identical to the historical per-round ``run_pofl``
Python loop (pinned by tests/test_sim.py):

    key = PRNGKey(cfg.seed)
    k_chan_init, key = split(key)           # channel process init
    per round: key, k_round = split(key)
               k_batch, k_chan, k_sched, k_noise = split(k_round, 4)

Three entry points:

  * :meth:`SimEngine.init` — build the initial :class:`SimState` (pure; the
    seed may be a traced scalar, so lattice cells vmap over it).
  * :meth:`SimEngine.scan_rounds` — the pure scanned program
    ``(state, t_ints, do_eval, noise_power, alpha) -> (state, RoundRecord)``;
    ``repro.sim.lattice`` vmaps this across cells. ``noise_power``/``alpha``
    may be traced (lattice axes); anything structural is static.
  * :meth:`SimEngine.run_with_history` — the ``run_pofl``-compatible driver:
    a single-STATIC-length active-mask scan per segment between eval rounds
    (inactive tail rounds are ``lax.cond`` no-ops that touch neither the
    PRNG chain nor the carry), evaluate with an arbitrary Python ``eval_fn``
    on the host, return ``(params, History)``. One trace per (engine,
    segment length) — not per distinct chunk length.

Engines themselves are cached across ``run_pofl`` calls by
:func:`cached_engine`, keyed by (task identity, cfg-minus-seed — which
includes the aggregation backend — channel config, scenario): a repeat call
with the same config reuses both the engine object and every jit trace it
has accumulated (:func:`engine_cache_stats` exposes hit/miss counters).
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import OrderedDict
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import local_update
from repro.core.channel import ChannelConfig
from repro.core.metrics import RoundDiagnostics, zero_round_health
from repro.core.pofl import (
    DeviceData, History, ModelShard, POFLConfig, round_algorithm,
)
from repro.obs.config import DEFAULT_OBS, ObsConfig
from repro.obs.profile import maybe_profile, profiling_enabled
from repro.obs.registry import counter_add, metric_value, reset_metrics
from repro.obs.spans import span
from repro.sim.scenario import make_channel_process
from repro.sim.tasks import TaskEval, zero_eval_record

# per-engine cap on cached AOT lattice executables (LRU eviction)
_LATTICE_EXECUTABLES_MAX = 8

# The cfg.policy sentinel of a POLICY-FUSED engine (``repro.sim.lattice``
# with ``fuse_policies=True``): the policy is a traced per-cell input
# (``policy_id``), so the engine's static policy string is deliberately not
# a real policy — it only keys the engine cache, making the whole
# multi-policy lattice ONE cache entry (and one compile).
FUSED_POLICY = "__fused__"

# The cfg.local_algorithm sentinel of an ALGORITHM-FUSED engine
# (``repro.sim.lattice`` with a multi-algorithm ``LatticeSpec``): the
# algorithm is a traced per-cell input (``algorithm_id``), so the engine's
# static algorithm string is deliberately not a real algorithm — it only
# keys the engine cache, making the whole multi-algorithm lattice ONE cache
# entry (and one compile). Same design as :data:`FUSED_POLICY`.
FUSED_ALGORITHM = "__fused__"


class SimState(NamedTuple):
    """The donated scan carry: everything that evolves across rounds.

    ``alg`` is the per-device local-algorithm state
    (:class:`~repro.core.local_update.AlgState` — FedDyn h_i / SCAFFOLD c_i);
    its default ``None`` flattens to an EMPTY pytree subtree, so stateless
    algorithms (the legacy fedavg path included) keep the carry structure —
    and every pinned trajectory — bit-identical to the pre-algorithm-axis
    engine (the PR-6 ``diag=None`` trick).
    """

    params: Any       # model pytree
    key: jax.Array    # PRNG chain
    chan: Any         # channel-process state pytree
    alg: Any = None   # local-algorithm state (AlgState), or None (stateless)


class RoundRecord(NamedTuple):
    """Per-round on-device metric record (stacked over rounds by the scan).

    ``diag`` is the :class:`~repro.core.metrics.RoundDiagnostics` subtree
    when the engine's :class:`~repro.obs.config.ObsConfig` asks for
    diagnostics, else ``None`` — which flattens to an EMPTY pytree subtree,
    so the off-path record has exactly the seed's leaves (pinned
    trajectories, ``launch.distributed`` serialization, and the gather
    programs all see an unchanged structure).

    ``eval`` applies the same trick to the model-task eval curves
    (``repro.sim.tasks``): it is the structured
    :class:`~repro.sim.tasks.EvalRecord` when the engine's ``eval_fn`` is a
    :class:`~repro.sim.tasks.TaskEval`, else ``None`` (OFF by default) —
    legacy tuple eval_fns and eval-less runs keep the seed's exact record
    pytree, so every pre-existing pinned trajectory stays bitwise unchanged.

    ``health`` is the fourth application of the same trick: the
    :class:`~repro.core.metrics.RoundHealth` non-finite quarantine counters
    when ``POFLConfig.on_nonfinite="skip"``, else ``None`` — the default
    "propagate" keeps the seed's exact record pytree and zero new ops.
    """

    e_com: jnp.ndarray        # Eq. 15 closed-form communication distortion
    e_var: jnp.ndarray        # realized global update variance (Thm. 1)
    grad_norm: jnp.ndarray    # ||ŷ^t||
    n_scheduled: jnp.ndarray  # realized |S^t|
    loss: jnp.ndarray         # eval loss (0 where not evaluated)
    acc: jnp.ndarray          # eval accuracy (0 where not evaluated)
    diag: Any = None          # RoundDiagnostics taps, or None (default)
    eval: Any = None          # tasks.EvalRecord subtree, or None (default)
    health: Any = None        # RoundHealth quarantine taps, or None (default)


# the always-present scalar record fields (diag/eval are optional subtrees)
_RECORD_SCALARS = ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")


def _zero_record(
    diagnostics: bool = False, task_eval: bool = False, health: bool = False
) -> RoundRecord:
    """A zero record matching the engine's record pytree (the inactive
    ``lax.cond`` branch must mirror ``round_body``'s structure exactly)."""
    scalars = [jnp.zeros((), jnp.float32) for _ in _RECORD_SCALARS]
    diag = None
    if diagnostics:
        diag = RoundDiagnostics(
            *(jnp.zeros((), jnp.float32) for _ in RoundDiagnostics._fields)
        )
    return RoundRecord(
        *scalars, diag=diag, eval=zero_eval_record() if task_eval else None,
        health=zero_round_health() if health else None,
    )


def _default_channel_cfg(cfg: POFLConfig) -> ChannelConfig:
    return ChannelConfig(
        n_devices=cfg.n_devices,
        tx_power=cfg.tx_power,
        noise_power=cfg.noise_power,
    )


class SimEngine:
    """Scan-over-rounds engine for one (task, config, channel scenario).

    Args:
      loss_fn: per-device loss ``f(params, x, y)`` (jax-traceable).
      data:    stacked per-device :class:`DeviceData` (equal shards or
        padded heterogeneous shards with ``n_samples``).
      cfg:     :class:`POFLConfig` (policy/sampler/|S|/batch/backend are
        static).
      channel_cfg: physical-layer constants; defaults to the config the
        historical ``run_pofl`` built from ``cfg``.
      scenario: channel-process name from ``sim.scenario.CHANNEL_SCENARIOS``.
      scenario_params: extra kwargs for the scenario (e.g. ``corr=0.95``).
      eval_fn: optional *traceable* ``params -> (loss, acc)`` evaluated
        inside the scan on rounds flagged by ``do_eval`` (used by the
        lattice; ``run_with_history`` instead takes an arbitrary Python
        callable and evaluates between chunks).

    ``mesh`` (a ``jax.sharding.Mesh`` or None) is carried as engine identity:
    the engine itself never reads it — input placement decides where the
    lattice program runs — but meshed and unmeshed engines must not share
    trace counters or cache slots (see :func:`cached_engine`), so it keys
    both.

    ``n_traces`` counts how many times the chunked scan has been (re)traced —
    the CI retrace guard asserts it stays flat across repeat ``run_pofl``
    calls with the same config. ``n_lattice_traces`` is the same counter for
    the vmapped-cells lattice program (:meth:`run_lattice_cells`).
    """

    def __init__(
        self,
        loss_fn: Callable,
        data: DeviceData,
        cfg: POFLConfig,
        channel_cfg: ChannelConfig | None = None,
        scenario: str = "static_rayleigh",
        scenario_params: dict | None = None,
        eval_fn: Callable | None = None,
        mesh: Any | None = None,
        obs: ObsConfig | None = None,
    ):
        self.loss_fn = loss_fn
        self.data = data
        self.cfg = cfg
        self.channel_cfg = channel_cfg or _default_channel_cfg(cfg)
        self.process = make_channel_process(
            scenario, self.channel_cfg, **(scenario_params or {})
        )
        self.eval_fn = eval_fn
        # A TaskEval (repro.sim.tasks) upgrades the record pytree with the
        # structured ``eval`` subtree; any other eval_fn keeps it None (the
        # empty-subtree OFF default — pinned trajectories stay bitwise).
        self._task_eval = eval_fn if isinstance(eval_fn, TaskEval) else None
        self.mesh = mesh
        # hard error on unknown algorithm names at engine construction (the
        # FUSED_ALGORITHM sentinel is the lattice's cache-key marker: the
        # per-cell traced algorithm_id does the real dispatch)
        if cfg.local_algorithm != FUSED_ALGORITHM:
            local_update.algorithm_id(cfg.local_algorithm)
        if cfg.on_nonfinite not in ("propagate", "skip"):
            raise ValueError(
                "POFLConfig.on_nonfinite must be 'propagate' or 'skip', "
                f"got {cfg.on_nonfinite!r}"
            )
        # A 2-D ("cells", "model") mesh with |model| > 1 switches the round
        # pipeline to the model-sharded hot path (core.pofl.ModelShard):
        # explicit shard_map blocks over the model axis, so — unlike the
        # cells axis, where input placement alone partitions the program —
        # the engine must know about it. |model| == 1 (incl. the 1-D mesh)
        # keeps model_shard None and the trace bit-identical to unsharded.
        self._model_shard = None
        if (
            mesh is not None
            and "model" in getattr(mesh, "axis_names", ())
            and int(mesh.shape["model"]) > 1
        ):
            self._model_shard = ModelShard(mesh=mesh)
        # static observability config: flipping `diagnostics` selects a
        # different traced program, so it keys the engine cache (a
        # diagnostics engine never shares jit traces with the plain one)
        self.obs = obs or DEFAULT_OBS
        self.n_traces = 0  # chunk-scan trace counter (see class docstring)
        self.n_lattice_traces = 0  # lattice-program trace counter
        self.n_compiles = 0  # AOT lattice compiles (one per arg signature)
        self.compile_seconds = 0.0  # trace+compile wall time of those
        # Donating the carry on CPU only triggers "donation not implemented"
        # warnings; donate on accelerators where it buys in-place reuse.
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._chunk_jit = jax.jit(
            self._chunk, static_argnames=("n_steps",), donate_argnums=donate
        )
        self._donating = bool(donate)
        # Under a model-sharded mesh the cell vmap must NAME its batch axis
        # (spmd_axis_name): the shard_map blocks inside the cell body are
        # manual over BOTH mesh axes, so the vmapped dimension has to map
        # onto the "cells" axis explicitly. Unsharded/|model|==1 engines
        # keep the anonymous vmap — the seed's exact trace.
        vmap_kw = {}
        if self._model_shard is not None:
            vmap_kw["spmd_axis_name"] = mesh.axis_names[0]
        self._lattice_jit = jax.jit(
            jax.vmap(
                self._lattice_cell, in_axes=(None, None, None, 0, 0, 0),
                **vmap_kw,
            )
        )
        self._fused_lattice_jit = jax.jit(
            jax.vmap(
                self._fused_lattice_cell,
                in_axes=(None, None, None, 0, 0, 0, 0),
                **vmap_kw,
            )
        )
        self._fused_alg_lattice_jit = jax.jit(
            jax.vmap(
                self._fused_alg_lattice_cell,
                in_axes=(None, None, None, 0, 0, 0, 0, 0),
                **vmap_kw,
            )
        )
        # the CHUNKED program family (sim.resilience): init and scan are
        # separate executables so a sweep can re-enter from a persisted
        # carry. Both are policy-fused; *_alg adds the traced algorithm axis.
        self._init_lattice_jit = jax.jit(
            jax.vmap(self._init_lattice_cell, in_axes=(None, 0), **vmap_kw)
        )
        self._init_alg_lattice_jit = jax.jit(
            jax.vmap(self._init_alg_lattice_cell, in_axes=(None, 0), **vmap_kw)
        )
        self._chunk_lattice_jit = jax.jit(
            jax.vmap(
                self._chunk_lattice_cell,
                in_axes=(0, None, None, None, 0, 0, 0, 0),
                **vmap_kw,
            )
        )
        self._chunk_alg_lattice_jit = jax.jit(
            jax.vmap(
                self._chunk_alg_lattice_cell,
                in_axes=(0, None, None, None, 0, 0, 0, 0, 0),
                **vmap_kw,
            )
        )
        # AOT ``lower().compile()`` executable cache: arg signature →
        # compiled lattice program (see :meth:`_aot_lattice_executable`).
        # Bounded LRU, same rationale as PR 4's gather-jit cache: each entry
        # pins a full XLA executable, so a long-lived process sweeping many
        # lattice shapes must evict, not accumulate.
        self._lattice_executables: OrderedDict[tuple, Any] = OrderedDict()

    # -- state construction -------------------------------------------------

    def init(self, params0, seed, fused_algorithms: bool = False) -> SimState:
        """Initial carry. ``seed`` may be traced (lattice vmaps over it).

        ``fused_algorithms=True`` (the traced-``algorithm_id`` lattice cell)
        builds the FULL :class:`~repro.core.local_update.AlgState` — every
        ``lax.switch`` branch is traced, so the carry must hold the union of
        all algorithms' state. Otherwise the state follows the static
        ``cfg.local_algorithm`` (``None`` — an empty subtree — for stateless
        algorithms, keeping the legacy carry structure bitwise)."""
        key = jax.random.PRNGKey(seed)
        k_chan_init, key = jax.random.split(key)
        chan = self.process.init(k_chan_init)
        return SimState(
            params=params0, key=key, chan=chan,
            alg=self._init_alg_state(params0, fused_algorithms),
        )

    def _init_alg_state(self, params0, fused_algorithms: bool):
        full = fused_algorithms or self.cfg.local_algorithm == FUSED_ALGORITHM
        if not full and self.cfg.local_algorithm in local_update.STATELESS:
            return None  # zero new leaves, zero new ops — the legacy carry
        # static size only (no ravel ops enter the trace for the zeros init)
        dim = sum(
            int(np.prod(np.shape(leaf))) for leaf in jax.tree.leaves(params0)
        )
        return local_update.init_state(
            self.cfg.local_algorithm, self.cfg.n_devices, dim, full=full
        )

    # -- the scanned program ------------------------------------------------

    def scan_rounds(
        self,
        state: SimState,
        t_ints: jnp.ndarray,       # (T,) int32 round indices
        do_eval: jnp.ndarray,      # (T,) bool — run eval_fn this round
        noise_power=None,          # traced scalar or None → cfg.noise_power
        alpha=None,                # traced scalar or None → cfg.alpha
        active: jnp.ndarray | None = None,  # (T,) bool — mask padded rounds
        policy_id=None,            # traced int32 or None → cfg.policy string
        algorithm_id=None,         # traced int32 or None → cfg.local_algorithm
        fault_round=None,          # traced int32 or None → no injection hook
    ) -> tuple[SimState, RoundRecord]:
        """Pure scan over rounds; vmap-safe (xs stay unbatched, so the eval
        ``lax.cond`` remains a genuine branch, not a select).

        ``active=None`` (the lattice path) scans every round unconditionally.
        With an ``active`` mask (the ``run_with_history`` static-length
        path), inactive rounds are genuine ``lax.cond`` no-ops: the carry —
        params, PRNG chain, channel state — passes through untouched, so a
        padded scan of the same active prefix is bit-identical to an unpadded
        one.

        ``fault_round`` (``sim.resilience``'s NaN-injection hook, a traced
        per-cell int32) rides into ``round_algorithm`` as a VALUE — ``-1``
        never fires — so faulted and unfaulted cells share one program;
        ``None`` (every pre-existing path) adds no ops at all.
        """

        def round_body(st: SimState, t_int, ev):
            t = t_int.astype(jnp.float32)
            key, k_round = jax.random.split(st.key)
            k_batch, k_chan, k_sched, k_noise = jax.random.split(k_round, 4)
            chan, h, avail = self.process.step(st.chan, k_chan)
            params, alg, m = round_algorithm(
                self.loss_fn, self.data, self.cfg, st.params, h,
                k_batch, k_sched, k_noise, t,
                noise_power=noise_power, alpha=alpha,
                # processes that never drop skip the masking entirely →
                # bit-identical to the legacy static path
                avail=avail if self.process.can_drop else None,
                policy_id=policy_id,
                diagnostics=self.obs.diagnostics,
                model_shard=self._model_shard,
                alg_state=st.alg,
                algorithm_id=algorithm_id,
                fault_round=fault_round,
            )
            ev_rec = None
            if self.eval_fn is None:
                loss = acc = jnp.zeros(())
            elif self._task_eval is not None:
                # model-task eval: one cond produces the full EvalRecord; its
                # loss/acc also fill the legacy always-present record fields
                ev_rec = jax.lax.cond(
                    ev,
                    self._task_eval.record,
                    lambda p: zero_eval_record(),
                    params,
                )
                loss, acc = ev_rec.loss, ev_rec.acc
            else:
                loss, acc = jax.lax.cond(
                    ev,
                    lambda p: tuple(
                        jnp.asarray(v, jnp.float32) for v in self.eval_fn(p)
                    ),
                    lambda p: (jnp.zeros(()), jnp.zeros(())),
                    params,
                )
            rec = RoundRecord(
                e_com=m.e_com, e_var=m.e_var, grad_norm=m.grad_norm,
                n_scheduled=m.n_scheduled, loss=loss, acc=acc, diag=m.diag,
                eval=ev_rec, health=m.health,
            )
            return SimState(params=params, key=key, chan=chan, alg=alg), rec

        if active is None:

            def body(st, x):
                t_int, ev = x
                return round_body(st, t_int, ev)

            xs: tuple = (t_ints, do_eval)
        else:

            def body(st, x):
                t_int, ev, act = x
                return jax.lax.cond(
                    act,
                    lambda s: round_body(s, t_int, ev),
                    lambda s: (
                        s,
                        _zero_record(
                            self.obs.diagnostics, self._task_eval is not None,
                            self.cfg.on_nonfinite == "skip",
                        ),
                    ),
                    st,
                )

            xs = (t_ints, do_eval, active)

        return jax.lax.scan(body, state, xs)

    # -- the vmapped lattice program ----------------------------------------

    def _lattice_cell(self, params0, t_ints, do_eval, noise_power, alpha, seed):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        state = self.init(params0, seed)
        _, recs = self.scan_rounds(
            state, t_ints, do_eval, noise_power=noise_power, alpha=alpha
        )
        return recs

    def _fused_lattice_cell(
        self, params0, t_ints, do_eval, noise_power, alpha, seed, policy_id
    ):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        state = self.init(params0, seed)
        _, recs = self.scan_rounds(
            state, t_ints, do_eval, noise_power=noise_power, alpha=alpha,
            policy_id=policy_id,
        )
        return recs

    def _fused_alg_lattice_cell(
        self, params0, t_ints, do_eval, noise_power, alpha, seed,
        policy_id, algorithm_id,
    ):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        state = self.init(params0, seed, fused_algorithms=True)
        _, recs = self.scan_rounds(
            state, t_ints, do_eval, noise_power=noise_power, alpha=alpha,
            policy_id=policy_id, algorithm_id=algorithm_id,
        )
        return recs

    # -- the chunked (checkpointable) lattice program family ---------------
    # sim.resilience splits init and scan into separate executables: the
    # init program builds the batched carry once, the chunk program advances
    # it `len(t_ints)` rounds and RETURNS it — so the full donated carry can
    # be persisted between chunks and re-entered bit-identically.

    def _init_lattice_cell(self, params0, seed):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        return self.init(params0, seed)

    def _init_alg_lattice_cell(self, params0, seed):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        return self.init(params0, seed, fused_algorithms=True)

    def _chunk_lattice_cell(
        self, state, t_ints, do_eval, active, noise_power, alpha,
        policy_id, fault_round,
    ):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        return self.scan_rounds(
            state, t_ints, do_eval, noise_power=noise_power, alpha=alpha,
            active=active, policy_id=policy_id, fault_round=fault_round,
        )

    def _chunk_alg_lattice_cell(
        self, state, t_ints, do_eval, active, noise_power, alpha,
        policy_id, algorithm_id, fault_round,
    ):
        self.n_lattice_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.lattice_traces")
        return self.scan_rounds(
            state, t_ints, do_eval, noise_power=noise_power, alpha=alpha,
            active=active, policy_id=policy_id, algorithm_id=algorithm_id,
            fault_round=fault_round,
        )

    def init_lattice_states(
        self, params0, seed_b, fused_algorithms: bool = False
    ) -> SimState:
        """The batched initial carry for a chunked lattice run: ONE compiled
        ``vmap(init)`` dispatch over the flattened (B,) seed axis. The
        returned :class:`SimState` has every leaf batched on axis 0 — exactly
        the carry :meth:`run_lattice_chunk` advances — and doubles as the
        structure/sharding TEMPLATE a persisted checkpoint is restored into
        (``repro.checkpoint.load_pytree`` re-places leaves onto it, keeping
        the chunk executable's argument signature stable across resume)."""
        args = (jax.tree.map(jnp.asarray, params0), jnp.asarray(seed_b))
        mode = "init_alg" if fused_algorithms else "init"
        compiled = self._aot_lattice_executable(mode, args)
        return compiled(*args)

    def run_lattice_chunk(
        self, state_b: SimState, t_ints, do_eval, active,
        noise_b, alpha_b, policy_b, algorithm_b=None, fault_b=None,
    ) -> tuple[SimState, RoundRecord]:
        """Advance the batched carry ``len(t_ints)`` rounds → (carry', records).

        The chunked counterpart of :meth:`run_lattice_cells`: same vmapped
        cell axes (always policy-fused — a constant ``policy_b`` is fine),
        but the carry comes IN as an argument and comes BACK OUT, so
        ``sim.resilience`` can persist it between chunks. ``active`` masks
        padded tail rounds as genuine carry-preserving no-ops, so every chunk
        of a sweep — including a short final one — dispatches the SAME
        executable (one compile per signature; AOT-cached like the other
        modes). ``fault_b`` is the per-cell NaN-injection round (int32, -1 =
        never; defaults to all -1 — same program either way, it is an input
        value). Chunking is re-entry, not re-tracing: the carry holds the
        whole PRNG chain, so chunked and resumed runs replay identical
        per-round keys.
        """
        if policy_b is None:
            raise ValueError(
                "run_lattice_chunk is always policy-fused: pass policy_b "
                "(a constant array selects one policy)"
            )
        if fault_b is None:
            fault_b = jnp.full(np.shape(policy_b), -1, jnp.int32)
        args = (
            state_b, jnp.asarray(t_ints), jnp.asarray(do_eval),
            jnp.asarray(active), noise_b, alpha_b, policy_b,
        )
        if algorithm_b is not None:
            mode = "chunk_alg"
            args = args + (algorithm_b, jnp.asarray(fault_b))
        else:
            mode = "chunk"
            args = args + (jnp.asarray(fault_b),)
        compiled = self._aot_lattice_executable(mode, args)
        n_cells = int(np.shape(policy_b)[0]) if np.ndim(policy_b) else 1
        with maybe_profile("lattice"), span(
            "lattice.dispatch", fused=True, cells=n_cells, chunked=True
        ):
            out = compiled(*args)
            if profiling_enabled():
                out = jax.block_until_ready(out)
            return out

    @staticmethod
    def _arg_signature(leaf) -> tuple:
        """Hashable AOT-dispatch identity of one lattice argument: shape,
        dtype, weak-typedness, and placement (a committed ``NamedSharding``
        compiles a different — partitioned — program than the default
        single-device placement; jax shardings hash by device layout, so two
        equal meshes share a signature). Must never touch the leaf's VALUES:
        a process-spanning global array cannot be fetched."""
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:  # non-array leaf (never a global array)
            dtype = np.asarray(leaf).dtype
        return (
            tuple(np.shape(leaf)),
            str(dtype),
            bool(getattr(leaf, "weak_type", False)),
            getattr(leaf, "sharding", None),
        )

    def _aot_lattice_executable(self, mode, args: tuple):
        """The compiled lattice program for ``args`` — AOT, cached, counted.

        ``mode`` selects the jitted vmap program — ``False`` (plain cells),
        ``True`` (policy-fused), ``"fused_alg"`` (policy+algorithm-fused),
        ``"init"``/``"init_alg"`` (the chunked family's batched-carry init),
        ``"chunk"``/``"chunk_alg"`` (the carry-in/carry-out chunk scan) —
        and leads the executable key. The mode values are APPEND-ONLY (like
        the signature tuple itself): the historical ``False``/``True``
        entries keep their exact keys, new program families add new values.

        First call for an argument signature pays ``jit.lower(...).compile()``
        ONCE (wall time accumulated in ``compile_seconds``, count in
        ``n_compiles``) and keeps the resulting executable; repeats dispatch
        straight to it — no jit-cache lookup, no re-trace, and honest
        compile-vs-steady-state accounting for ``benchmarks/run.py``. The
        executable also exposes XLA's per-program ``cost_analysis`` /
        ``memory_analysis`` (see :meth:`lattice_cost_analysis`).
        """
        leaves, treedef = jax.tree.flatten(args)
        # mesh identity rides at the END of the key (append-only contract):
        # the engine cache already separates meshed engines, but the
        # executables of a shared-signature argset must still never alias
        # across mesh shapes if an engine is ever built bypassing the cache
        key = (
            mode, treedef, tuple(self._arg_signature(l) for l in leaves),
            _mesh_key(self.mesh),
        )
        compiled = self._lattice_executables.get(key)
        if compiled is None:
            fn = {
                False: self._lattice_jit,
                True: self._fused_lattice_jit,
                "fused_alg": self._fused_alg_lattice_jit,
                "init": self._init_lattice_jit,
                "init_alg": self._init_alg_lattice_jit,
                "chunk": self._chunk_lattice_jit,
                "chunk_alg": self._chunk_alg_lattice_jit,
            }[mode]
            t0 = time.perf_counter()
            with span("lattice.compile", fused=bool(mode)):
                compiled = fn.lower(*args).compile()
            dt = time.perf_counter() - t0
            self.compile_seconds += dt
            self.n_compiles += 1
            counter_add("lattice.n_compiles")
            counter_add("lattice.compile_seconds", dt, emit_event=False)
            self._lattice_executables[key] = compiled
            while len(self._lattice_executables) > _LATTICE_EXECUTABLES_MAX:
                self._lattice_executables.popitem(last=False)
        else:
            self._lattice_executables.move_to_end(key)
        return compiled

    def run_lattice_cells(
        self, params0, t_ints, do_eval, noise_b, alpha_b, seed_b,
        policy_b=None, algorithm_b=None,
    ) -> RoundRecord:
        """One compiled (vmap-over-cells ∘ scan-over-rounds) dispatch.

        ``noise_b``/``alpha_b``/``seed_b`` are the flattened (B,) cell axes;
        when they carry a ``NamedSharding`` over a cell mesh (see
        ``sim.lattice``) the whole program partitions along that axis —
        computation follows the committed input placement, so the engine
        needs no sharded/unsharded code split. ``policy_b`` (flattened (B,)
        int32 ``scheduling.POLICY_IDS``) switches to the POLICY-FUSED
        program: the policy becomes one more vmapped cell axis, so a whole
        multi-policy lattice is ONE compile. ``algorithm_b`` (flattened (B,)
        int32 ``local_update.ALGORITHM_IDS``, requires ``policy_b``) switches
        further to the policy+ALGORITHM-fused program — the local-update
        algorithm joins the vmapped cell axes, so a whole (algorithm × policy
        × noise × α × seed) lattice is still ONE compile. Dispatch is AOT
        (``lower().compile()`` on first signature, cached executable after),
        so repeat calls through :func:`cached_engine` re-trace zero times
        (``n_lattice_traces`` stays flat) and recompile zero times
        (``n_compiles`` stays flat).
        """
        args = (
            jax.tree.map(jnp.asarray, params0),
            jnp.asarray(t_ints), jnp.asarray(do_eval),
            noise_b, alpha_b, seed_b,
        )
        if algorithm_b is not None:
            if policy_b is None:
                raise ValueError(
                    "algorithm_b requires policy_b: the algorithm-fused "
                    "program fuses the policy axis too (constant policy_b "
                    "is fine)"
                )
            mode = "fused_alg"
            args = args + (policy_b, algorithm_b)
        elif policy_b is not None:
            mode = True
            args = args + (policy_b,)
        else:
            mode = False
        compiled = self._aot_lattice_executable(mode, args)
        n_cells = int(np.shape(seed_b)[0]) if np.ndim(seed_b) else 1
        # the dispatch span measures HOST dispatch wall only (jax dispatch is
        # async — device execution completes under the caller's
        # block_until_ready / device_get, covered by the lattice.sweep span).
        # Under REPRO_OBS_PROFILE the dispatch blocks inside the profiler
        # context so the capture contains the device execution too.
        with maybe_profile("lattice"), span(
            "lattice.dispatch", fused=bool(mode), cells=n_cells
        ):
            out = compiled(*args)
            if profiling_enabled():
                out = jax.block_until_ready(out)
            return out

    def lattice_cost_analysis(self) -> dict:
        """XLA ``cost_analysis`` (flops/bytes) of the most recent lattice
        executable, as a flat dict ({} before the first compile).

        jax-version compat: newer jax returns the dict directly, 0.4.x wraps
        it in a one-element list (same shim as ``launch.dryrun``).
        """
        if not self._lattice_executables:
            return {}
        compiled = next(reversed(self._lattice_executables.values()))
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return dict(cost)

    def lattice_memory_analysis(self):
        """XLA ``memory_analysis`` (argument/output/temp bytes) of the most
        recent lattice executable, or None before the first compile."""
        if not self._lattice_executables:
            return None
        return next(reversed(self._lattice_executables.values())).memory_analysis()

    def _chunk(self, state: SimState, t0, n_active, n_steps: int):
        self.n_traces += 1  # Python body runs only when (re)tracing
        counter_add("engine.traces")
        steps = jnp.arange(n_steps, dtype=jnp.int32)
        t_ints = t0 + steps
        do_eval = jnp.zeros((n_steps,), bool)
        return self.scan_rounds(
            state, t_ints, do_eval, active=steps < n_active
        )

    # -- run_pofl-compatible driver -----------------------------------------

    def run_with_history(
        self,
        params0,
        n_rounds: int,
        eval_fn: Callable | None = None,
        eval_every: int = 5,
        seed: int | None = None,
    ) -> tuple[Any, History]:
        """Chunked scan with host-side eval between chunks → (params, History).

        ``eval_fn`` may be any Python callable (it never enters the trace);
        metrics sync to host once per chunk instead of once per round.
        ``seed`` defaults to ``cfg.seed`` (cached engines are shared across
        seeds, so ``run_pofl`` passes the current call's seed explicitly).

        Compile-cost note: every segment between eval boundaries runs as ONE
        static-length scan (length = the longest segment) with an active-mask
        prefix, so a cold call traces the scan exactly once — and repeat
        calls through :func:`cached_engine` trace zero times. Sweeps should
        still use ``sim.lattice`` (one compile per policy for ALL cells).
        """
        params0 = jax.tree.map(jnp.asarray, params0)
        if self._donating:
            params0 = jax.tree.map(lambda x: jnp.array(x, copy=True), params0)
        seed = self.cfg.seed if seed is None else seed
        state = self.init(params0, seed)

        hist = History(loss=[], e_com=[], e_var=[], test_acc=[], test_round=[])
        if eval_fn is None:
            eval_ts: list[int] = []
        else:
            eval_ts = sorted(
                {t for t in range(n_rounds) if t % eval_every == 0}
                | ({n_rounds - 1} if n_rounds else set())
            )

        # segment boundaries: one host sync after each eval round + the tail
        segments: list[tuple[int, int]] = []  # (t0, n_active)
        t = 0
        for stop in [et + 1 for et in eval_ts] + [n_rounds]:
            if stop > t:
                segments.append((t, stop - t))
                t = stop
        n_steps = max((n for _, n in segments), default=0)

        t = 0
        for t0, n_active in segments:
            state, recs = self._chunk_jit(
                state,
                jnp.asarray(t0, jnp.int32),
                jnp.asarray(n_active, jnp.int32),
                n_steps=n_steps,
            )
            hist.e_com.extend(np.asarray(recs.e_com)[:n_active].tolist())
            hist.e_var.extend(np.asarray(recs.e_var)[:n_active].tolist())
            t = t0 + n_active
            if eval_fn is not None and t - 1 in eval_ts and t - 1 not in hist.test_round:
                loss, acc = eval_fn(state.params)
                hist.loss.append(float(loss))
                hist.test_acc.append(float(acc))
                hist.test_round.append(t - 1)
        return state.params, hist


# --------------------------------------------------------------------------
# cross-call engine cache
# --------------------------------------------------------------------------

_ENGINE_CACHE: OrderedDict[tuple, SimEngine] = OrderedDict()
_ENGINE_CACHE_MAX = 64
# hit/miss counters live in the obs registry under ``engine_cache.`` —
# :func:`engine_cache_stats` stays as the thin shim the tests/benchmarks use


def _data_key(data: DeviceData) -> tuple:
    """Identity key for a stacked dataset (object identity + shape guard)."""
    ns = data.n_samples
    return (
        id(data.features),
        id(data.labels),
        None if ns is None else id(ns),
        tuple(np.shape(data.features)),
        tuple(np.shape(data.labels)),
    )


def _freeze(obj):
    """Recursively hashable view of a scenario-params value: dicts become
    sorted item tuples, lists/tuples become tuples, arrays (numpy or jax)
    become (tag, dtype, shape, values) tuples — so any params SimEngine
    accepts also key the cache instead of raising TypeError."""
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    if isinstance(obj, (np.ndarray, np.generic, jax.Array)):
        arr = np.asarray(obj)
        return ("arr", str(arr.dtype), arr.shape, tuple(arr.ravel().tolist()))
    return obj


def _mesh_key(mesh) -> tuple | None:
    """Hashable identity of a ``jax.sharding.Mesh`` (None stays None).

    Axis names, logical shape, and the flat (device id, owning process)
    pairs — two meshes over the same devices in the same layout are the same
    engine, anything else (different device set, different order, devices
    from a different process span) is not.
    """
    if mesh is None:
        return None
    return (
        tuple(mesh.axis_names),
        tuple(np.shape(mesh.devices)),
        tuple((d.id, d.process_index) for d in np.ravel(mesh.devices)),
    )


def _process_topology_key() -> tuple:
    """The process topology this engine's traces were built under.

    A ``jax.distributed`` run compiles SPMD programs against the global
    device count and this process's rank, so traces from one topology must
    never be replayed under another — within one process lifetime the
    topology cannot change, but the key keeps the cache honest (and its
    entries debuggable) all the same.
    """
    return (jax.process_count(), jax.process_index())


def cached_engine(
    loss_fn: Callable,
    data: DeviceData,
    cfg: POFLConfig,
    channel_cfg: ChannelConfig | None = None,
    scenario: str = "static_rayleigh",
    scenario_params: dict | None = None,
    eval_fn: Callable | None = None,
    mesh: Any | None = None,
    obs: ObsConfig | None = None,
) -> SimEngine:
    """Return a (possibly shared) :class:`SimEngine` for this task + config.

    The key is ``(loss_fn, data identity, cfg with seed zeroed — including
    the aggregation backend — channel_cfg, scenario, eval_fn identity, mesh
    identity, process topology, obs config)``: calls that differ only by seed
    share one engine and therefore every jit trace it has already paid for.
    Model tasks (``repro.sim.tasks``) key by the same identities — a
    :func:`~repro.sim.tasks.make_model_task` task is memoized, so its
    ``loss_fn``/``data``/``TaskEval`` objects (and hence this cache entry)
    are stable across rebuilds of the same task arguments. A
    mesh-keyed engine never collides with the unsharded one (or with a
    differently-shaped mesh, or one spanning a different ``jax.distributed``
    process set), so per-engine trace counters stay meaningful under
    sharding. An ``obs`` with diagnostics on is a SECOND cache key for the
    same task — the taps change the traced program, so the diagnostics
    engine accumulates its own traces/executables; repeat diagnostics calls
    still re-trace zero times. The
    cache is a bounded LRU (evicts least recently used); entries pin their
    ``data`` arrays alive, which is the point — eviction releases them.
    """
    obs = obs or DEFAULT_OBS
    key = (
        loss_fn,
        _data_key(data),
        dataclasses.replace(cfg, seed=0),
        channel_cfg,
        scenario,
        _freeze(scenario_params),
        eval_fn,
        _mesh_key(mesh),
        _process_topology_key(),
        obs,
        # the fused backend's dispatch reads this env var at trace time, so
        # toggling it must not replay a stale trace (parity tests flip it)
        os.environ.get("REPRO_PALLAS_INTERPRET", ""),
    )
    engine = _ENGINE_CACHE.get(key)
    if engine is not None:
        counter_add("engine_cache.hits")
        _ENGINE_CACHE.move_to_end(key)
        return engine
    counter_add("engine_cache.misses")
    engine = SimEngine(
        loss_fn, data, cfg,
        channel_cfg=channel_cfg,
        scenario=scenario,
        scenario_params=scenario_params,
        eval_fn=eval_fn,
        mesh=mesh,
        obs=obs,
    )
    _ENGINE_CACHE[key] = engine
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_MAX:
        _ENGINE_CACHE.popitem(last=False)
    return engine


def engine_cache_stats() -> dict:
    """Snapshot of the cross-call engine cache: hits/misses/size.

    Thin shim over the obs registry (``engine_cache.hits`` / ``.misses``) —
    kept so every historical caller and test keeps working unchanged.
    """
    return {
        "hits": int(metric_value("engine_cache.hits")),
        "misses": int(metric_value("engine_cache.misses")),
        "size": len(_ENGINE_CACHE),
    }


def lattice_memory_stats() -> dict:
    """Per-device HBM footprint of the most recent AOT lattice executable
    across the cached engines: ``{"per_device_hbm_bytes", "argument_bytes",
    "output_bytes", "temp_bytes", "mesh_shape"}`` (zeros / None before any
    compile). XLA's ``memory_analysis`` is already PER-DEVICE under SPMD
    partitioning, so ``per_device_hbm_bytes = argument + output + temp`` is
    the number ``BENCH_sim.json`` reports — it shrinks as the model axis
    grows at fixed D.
    """
    stats = {
        "per_device_hbm_bytes": 0,
        "argument_bytes": 0,
        "output_bytes": 0,
        "temp_bytes": 0,
        "mesh_shape": None,
    }
    # most recently *used* executable across engines: walk engines in cache
    # (LRU) order, newest last, and take the last one holding an executable
    for engine in _ENGINE_CACHE.values():
        mem = engine.lattice_memory_analysis()
        if mem is None:
            continue
        arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
        out_b = int(getattr(mem, "output_size_in_bytes", 0))
        tmp_b = int(getattr(mem, "temp_size_in_bytes", 0))
        stats = {
            "per_device_hbm_bytes": arg_b + out_b + tmp_b,
            "argument_bytes": arg_b,
            "output_bytes": out_b,
            "temp_bytes": tmp_b,
            "mesh_shape": (
                None if engine.mesh is None
                else tuple(int(engine.mesh.shape[a]) for a in engine.mesh.axis_names)
            ),
        }
    return stats


def lattice_compile_stats() -> dict:
    """Aggregate AOT lattice-compile counters over every cached engine:
    ``{"n_compiles", "compile_seconds"}`` — the compile-vs-steady-state split
    ``benchmarks/run.py`` reports (engines dropped by ``reset_engine_cache``
    leave the aggregate, so scope a measurement with a reset first)."""
    engines = list(_ENGINE_CACHE.values())
    return {
        "n_compiles": sum(e.n_compiles for e in engines),
        "compile_seconds": sum(e.compile_seconds for e in engines),
    }


def reset_engine_cache() -> None:
    """Drop every cached engine and zero the hit/miss counters.

    Scoped: resets exactly the ``engine_cache.`` registry namespace —
    never the persistent-compile-cache counters (a CI warm-run guard reads
    those across the whole process lifetime) or span totals.
    """
    _ENGINE_CACHE.clear()
    reset_metrics("engine_cache.")
