"""Experiment lattices: whole paper sweeps as ONE vmapped+scanned program.

A :class:`LatticeSpec` names the sweep axes

    algorithms × policies × noise_powers × alphas × seeds   (× n_rounds scanned)

and :func:`run_lattice` compiles the ENTIRE lattice into a single program:
``vmap`` over the flattened (algorithm, policy, noise, alpha, seed) grid of
the engine's ``lax.scan`` over rounds. The policy axis is *traced* — each
cell carries an int32 ``policy_id`` dispatched by ``lax.switch``
(``core.scheduling.scheduling_probs_by_id``), so a 5-policy sweep pays ONE
trace and ONE XLA compile instead of five (the engine cache likewise holds
one entry per lattice, keyed by the ``FUSED_POLICY`` sentinel). The
local-update algorithm axis is traced the same way — a multi-algorithm
``spec.algorithms`` gives every cell an int32 ``algorithm_id`` dispatched
through ``core.local_update``'s append-only branch table (engine cache
keyed by the ``FUSED_ALGORITHM`` sentinel), so (algorithm × policy × noise
× α × seed) is STILL one trace and one compile; a single-algorithm spec
(the default ``("fedavg",)``) keeps the historical static dispatch and
traces today's exact program.
``fuse_policies=False`` keeps the per-policy Python loop (one compile per
policy, each over the same traced-dispatch cell program with a constant
``policy_id``) — pinned bit-identical to the fused path by
tests/test_fused_lattice.py. The historical ``cfg.policy`` STRING dispatch
remains the round engine's default (``run_pofl`` trajectories are pinned on
it) and is pinned against the traced dispatch bitwise at the
``scheduling_probs`` level; whole-lattice string-vs-switch comparisons are
dtype-exact up to the documented ≤1-ULP cross-program reduction wobble
(same phenomenon as the PR-4 multi-host ``e_var`` carve-out). Anything
shape-changing (n_devices, |S|, samplers) remains structural either way. Per-cell metrics
stay on device for the whole run and stream out exactly once at the end as
structured numpy records.

Compared to looping ``run_pofl`` over (policy × trial × sweep-point) — the
seed repo's benchmark harness — this removes the per-round host sync and the
per-(trial, sweep-point) recompiles; see benchmarks/run.py's ``BENCH_sim``
entry for the measured cells/sec (``compile_seconds`` vs
``steady_cells_per_sec`` — dispatch is AOT ``lower().compile()`` on the
engine, and ``repro.sim.compile_cache`` can persist the compiles across
processes).

Sharding: ``run_lattice(..., mesh=...)`` places the flattened cell axis —
which now spans policies too — on a ``jax.sharding.Mesh`` with
``NamedSharding(P("cells"))``: the grid is padded to a multiple of the mesh
size with dead cells (repeats of the last real cell) whose outputs are
masked off at unpadding, and the same vmapped+scanned program is reused
unchanged, so a 1-device mesh is bit-identical to the unsharded path (pinned
by tests/test_lattice_sharded.py). ``mesh`` may be a Mesh, a device count
(→ :func:`make_cell_mesh`), or None. Engines are cached across calls by
``sim.engine.cached_engine`` keyed on the mesh identity, so repeat sharded
calls re-trace zero times.

Multi-host: when the mesh spans processes (``jax.distributed`` initialized —
see ``repro.sim.multihost`` — and a global-device mesh from
:func:`~repro.sim.multihost.make_global_cell_mesh`), every process makes the
SAME ``run_lattice`` call but feeds only its addressable shard of the padded
cell grid (``shard_to_global`` assembly) and receives the full records back
via a tiled allgather (``gather_records``), so the returned
:class:`LatticeRecords` is identical on every host — dtype-exact against the
single-host run of the same spec, pinned by tests/test_multihost_lattice.py
through the ``repro.launch.distributed`` subprocess launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import local_update, scheduling
from repro.core.channel import ChannelConfig
from repro.core.pofl import DeviceData, POFLConfig
from repro.obs.config import ObsConfig
from repro.obs.sink import emit
from repro.obs.spans import span
from repro.sim.engine import FUSED_ALGORITHM, FUSED_POLICY, cached_engine
from repro.sim.multihost import (
    cell_model_mesh_over,
    cells_mesh_over,
    gather_records,
    mesh_spans_processes,
    shard_to_global,
)

_LOCAL_MESH_HINT = "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count)"


def make_cell_model_mesh(
    cells: int | None = None, model: int = 1
) -> jax.sharding.Mesh:
    """A 2-D ``("cells", "model")`` mesh over the first ``cells × model``
    LOCAL devices.

    The cells axis shards the flattened lattice grid exactly like the 1-D
    mesh; a ``model`` axis > 1 additionally shards the flat model dimension
    D of every cell — gradients, noise draws, params carry and ŷ are placed
    ``P(None, "model")`` so each device holds only ``D/model`` of every
    large tensor (see ``core.pofl.ModelShard``). ``cells=None`` takes every
    full group of ``model`` local devices. Process-spanning meshes come from
    ``repro.sim.multihost.make_global_cell_model_mesh``; on CPU CI, fake
    multi-device semantics come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    return cell_model_mesh_over(
        jax.local_devices(), cells, model, hint=_LOCAL_MESH_HINT
    )


def make_cell_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """A 1-D ``("cells",)`` mesh over the first ``n_devices`` LOCAL devices.

    ``None`` takes every local device. Genuinely local: under
    ``jax.distributed`` this builds from ``jax.local_devices()`` (this
    process's own devices — ``jax.devices()`` would return rank 0's devices
    on every rank); process-spanning meshes come from
    ``repro.sim.multihost.make_global_cell_mesh`` instead. On CPU CI, fake
    multi-device semantics come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes).
    """
    return cells_mesh_over(
        jax.local_devices(), n_devices, hint=_LOCAL_MESH_HINT,
    )


@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    """Sweep axes + schedule for one experiment lattice.

    ``noise_powers``, ``alphas`` and ``seeds`` are *vmapped* (batched into
    one program); ``policies`` is a Python loop (structural). Everything not
    named here comes from ``run_lattice``'s ``base_cfg``.
    """

    policies: tuple[str, ...] = ("pofl",)
    noise_powers: tuple[float, ...] = (1e-11,)
    alphas: tuple[float, ...] = (0.1,)
    seeds: tuple[int, ...] = (0,)
    n_rounds: int = 100
    eval_every: int = 5
    # local-update algorithms (core.local_update.ALGORITHMS names); the
    # default single-algorithm tuple keeps the historical static dispatch —
    # ≥2 names trace an int32 algorithm_id axis into the same fused program
    algorithms: tuple[str, ...] = ("fedavg",)

    @property
    def n_cells(self) -> int:
        return (
            len(self.algorithms)
            * len(self.policies)
            * len(self.noise_powers)
            * len(self.alphas)
            * len(self.seeds)
        )


class LatticeRecords(NamedTuple):
    """Structured per-cell records, axes (algorithm, policy, noise, alpha,
    seed, ...).

    The algorithm axis LEADS and is always present (size 1 for the default
    single-algorithm spec — legacy ``[p, n, a, s]`` indexing broadcasts
    unchanged). ``loss``/``acc`` are sub-sampled at ``eval_rounds`` (empty E
    axis when the lattice ran without an eval_fn).

    ``eval`` is the model-task eval subtree: a
    :class:`~repro.sim.tasks.EvalRecord` of ``(A, P, Nn, Na, Ns, E)`` curves
    when the lattice ran with a :class:`~repro.sim.tasks.TaskEval` eval_fn,
    else ``None`` — which flattens to an EMPTY pytree, so eval-off (and
    legacy-eval) records keep exactly the historical leaves (the ``diag``
    contract, applied to accuracy/loss curves).
    """

    axes: dict            # axis name -> coordinate list
    e_com: np.ndarray     # (A, P, Nn, Na, Ns, T)
    e_var: np.ndarray     # (A, P, Nn, Na, Ns, T)
    grad_norm: np.ndarray # (A, P, Nn, Na, Ns, T)
    n_scheduled: np.ndarray  # (A, P, Nn, Na, Ns, T)
    loss: np.ndarray      # (A, P, Nn, Na, Ns, E)
    acc: np.ndarray       # (A, P, Nn, Na, Ns, E)
    eval_rounds: np.ndarray  # (E,)
    diag: Any = None      # RoundDiagnostics of (A, P, Nn, Na, Ns, T) taps when
    #                       the lattice ran with ObsConfig(diagnostics=True)
    eval: Any = None      # tasks.EvalRecord of (A, P, Nn, Na, Ns, E) curves
    #                       when eval_fn was a tasks.TaskEval, else None
    health: Any = None    # core.metrics.RoundHealth of (A, P, Nn, Na, Ns, T)
    #                       quarantine counters when base_cfg.on_nonfinite=
    #                       "skip", else None (the diag empty-subtree contract)

    def cell(self, **coords) -> dict:
        """Select one sub-array per field by axis coordinates, e.g.
        ``records.cell(policy="pofl", seed=0)``."""
        idx: list[Any] = []
        for name in ("algorithm", "policy", "noise_power", "alpha", "seed"):
            if name in coords:
                idx.append(self.axes[name].index(coords.pop(name)))
            else:
                idx.append(slice(None))
        if coords:
            raise ValueError(f"unknown axes {sorted(coords)}")
        sel = tuple(idx)
        return {
            f: getattr(self, f)[sel]
            for f in ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")
        }


def run_lattice(
    loss_fn: Callable,
    data: DeviceData,
    params0,
    spec: LatticeSpec,
    base_cfg: POFLConfig | None = None,
    eval_fn: Callable | None = None,
    channel_cfg: ChannelConfig | None = None,
    scenario: str = "static_rayleigh",
    scenario_params: dict | None = None,
    mesh: jax.sharding.Mesh | int | tuple | None = None,
    fuse_policies: bool = True,
    fuse_algorithms: bool = True,
    obs: ObsConfig | None = None,
    _forced_algorithm_axis: bool = False,
) -> LatticeRecords:
    """Run the full lattice; ONE compiled (vmap ∘ scan) program for the spec.

    Args:
      eval_fn: traceable ``params -> (loss, acc)`` — evaluated inside the
        scan every ``spec.eval_every`` rounds (and on the last round).
      base_cfg: defaults for everything the spec doesn't sweep; its
        ``policy``/``noise_power``/``alpha``/``seed``/``local_algorithm``
        fields are overridden per cell (``spec.algorithms`` names the
        algorithm axis, like ``spec.policies`` names the policy axis). ``base_cfg.backend`` selects the aggregation backend for
        every cell (under the cell vmap the ``pallas_fused`` kernel batches
        into the trial-batched grid), and ``data`` may carry heterogeneous
        shards (``DeviceData.n_samples``) — the Eq. 34/35/37 weights follow
        the true m_i/M in every cell.
      mesh: shard the flattened cell axis over this ``jax.sharding.Mesh``
        (inputs are placed with ``NamedSharding(P(<first axis>))``). An int
        builds ``make_cell_mesh(mesh)``; a ``(cells, model)`` tuple builds
        ``make_cell_model_mesh(cells, model)``. The grid is padded to a
        multiple of the CELLS axis size (the full device count on a 1-D
        mesh) with dead cells that are dropped on unpadding; records,
        order, and values are unchanged (a 1-device mesh is bit-identical
        to ``mesh=None``). A 2-D ``("cells", "model")`` mesh with
        ``|model| > 1`` additionally shards the flat model dimension: the
        engine pads D to a multiple of ``|model| · tile_d``, places every
        flat-D leaf ``P(None, "model")``, and routes stats/aggregation
        through model-axis ``shard_map`` (``core.pofl.ModelShard``); the
        initial params are placed by ``launch.sharding.param_spec``.
        A process-spanning mesh (``sim.multihost.make_global_cell_mesh`` /
        ``make_global_cell_model_mesh`` under ``jax.distributed``)
        switches input feeding to per-process shard assembly and records to
        an allgather — every host returns the same full records.
      fuse_policies: True (default) folds the policy axis into the traced
        program — every cell carries an int32 ``policy_id``, the whole
        lattice is one engine-cache entry / one trace / one compile. False
        restores the per-policy Python loop — each policy compiles its own
        (smaller) program over the same traced-dispatch cell body with a
        constant ``policy_id`` axis, so records are bit-identical to the
        fused path; kept as the debugging/fallback route.
      fuse_algorithms: True (default) folds a multi-algorithm
        ``spec.algorithms`` axis into the traced program the same way —
        every cell carries an int32 ``algorithm_id`` through
        ``core.local_update``'s append-only ``lax.switch`` table, so
        (algorithm × policy × noise × α × seed) is still ONE compile. False
        loops per algorithm — each algorithm runs its own lattice over the
        same traced-dispatch cell program with a constant ``algorithm_id``
        axis (one compile per algorithm), bit-identical to the fused lanes;
        the debugging/fallback route, mirroring ``fuse_policies=False``.
        Single-algorithm specs (the default) never trace the algorithm axis:
        the engine dispatches statically on ``cfg.local_algorithm`` and the
        default ``("fedavg",)`` spec traces today's exact program.
      obs: observability config. ``ObsConfig(diagnostics=True)`` compiles
        the cheap per-round taps (:class:`repro.core.metrics.RoundDiagnostics`)
        into every cell and returns them as ``LatticeRecords.diag``; it keys
        a SECOND engine-cache entry, so repeat diagnostics sweeps still
        re-trace zero times. ``None``/default: program and records identical
        to before obs existed. Every sweep also times itself
        (``span("lattice.sweep")``) and emits one ``lattice`` JSONL event per
        engine dispatch when ``REPRO_OBS_DIR`` is set.
    """
    base_cfg = base_cfg or POFLConfig(n_devices=data.n_devices)
    algs = tuple(spec.algorithms)
    if not algs:
        raise ValueError("spec.algorithms must name at least one algorithm")
    for a in algs:
        local_update.algorithm_id(a)  # fail fast on unknown names

    if len(algs) > 1 and not fuse_algorithms:
        # per-algorithm Python loop: each algorithm re-enters run_lattice as
        # a single-algorithm spec FORCED onto the traced-dispatch cell
        # program (constant algorithm_id axis) — same cell program as the
        # fused lanes, so records are bit-identical; one compile per
        # algorithm (mirrors the fuse_policies=False cost model)
        per_alg = [
            run_lattice(
                loss_fn, data, params0,
                dataclasses.replace(spec, algorithms=(a,)),
                base_cfg=base_cfg, eval_fn=eval_fn, channel_cfg=channel_cfg,
                scenario=scenario, scenario_params=scenario_params,
                mesh=mesh, fuse_policies=fuse_policies, obs=obs,
                _forced_algorithm_axis=True,
            )
            for a in algs
        ]
        return _concat_algorithms(algs, per_alg)

    # the algorithm axis is traced iff >1 algorithm (fused) or forced by the
    # per-algorithm fallback loop; single-algorithm user specs keep the
    # historical static dispatch (default ("fedavg",) → today's exact program)
    traced_algs = len(algs) > 1 or _forced_algorithm_axis
    base_alg = FUSED_ALGORITHM if len(algs) > 1 else algs[0]

    if isinstance(mesh, int):
        mesh = make_cell_mesh(mesh)
    elif isinstance(mesh, tuple):
        mesh = make_cell_model_mesh(*mesh)

    t_ints = np.arange(spec.n_rounds, dtype=np.int32)
    if eval_fn is not None and spec.n_rounds:
        do_eval = (t_ints % spec.eval_every == 0) | (t_ints == spec.n_rounds - 1)
    else:
        do_eval = np.zeros(spec.n_rounds, bool)
    eval_rounds = t_ints[do_eval]

    # flattened vmap grid: (algorithm,) × (policy,) × noise × alpha × seed
    # when fused — algorithm-major then policy-major, so the fused flat order
    # equals the per-algorithm/per-policy stack orders
    grid_axes = [
        np.asarray(spec.noise_powers, np.float32),
        np.asarray(spec.alphas, np.float32),
        np.asarray(spec.seeds, np.int32),
    ]
    alg_ids = np.asarray(
        [local_update.algorithm_id(a) for a in algs], np.int32
    )
    if fuse_policies:
        pol_ids = np.asarray(
            [scheduling.policy_id(p) for p in spec.policies], np.int32
        )
        if traced_algs:
            grid_al, grid_p, grid_n, grid_a, grid_s = np.meshgrid(
                alg_ids, pol_ids, *grid_axes, indexing="ij"
            )
            cells = [
                grid_n.ravel(), grid_a.ravel(), grid_s.ravel(),
                grid_p.ravel(), grid_al.ravel(),
            ]
        else:
            grid_p, grid_n, grid_a, grid_s = np.meshgrid(
                pol_ids, *grid_axes, indexing="ij"
            )
            cells = [
                grid_n.ravel(), grid_a.ravel(), grid_s.ravel(), grid_p.ravel()
            ]
    elif traced_algs:
        grid_al, grid_n, grid_a, grid_s = np.meshgrid(
            alg_ids, *grid_axes, indexing="ij"
        )
        cells = [
            grid_n.ravel(), grid_a.ravel(), grid_s.ravel(), grid_al.ravel()
        ]
    else:
        grid_n, grid_a, grid_s = np.meshgrid(*grid_axes, indexing="ij")
        cells = [grid_n.ravel(), grid_a.ravel(), grid_s.ravel()]
    n_real = cells[0].size

    multihost = mesh_spans_processes(mesh)
    if mesh is not None:
        # pad the cell axis to a multiple of the CELLS-axis size with dead
        # cells (repeats of the last real cell — same shapes, outputs
        # discarded). On a 1-D mesh that is the device count; on a 2-D
        # (cells, model) mesh only the first axis shards cells.
        n_shards = int(mesh.shape[mesh.axis_names[0]])
        pad = (-n_real) % n_shards
        if pad:
            cells = [np.concatenate([c, np.repeat(c[-1:], pad)]) for c in cells]
        cell_sharding = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))

        if multihost:
            # every process holds the same deterministic grid; each commits
            # only the shards its own devices own
            def place(c):
                return shard_to_global(c, cell_sharding)
        else:
            def place(c):
                return jax.device_put(jnp.asarray(c), cell_sharding)

        if "model" in mesh.axis_names and int(mesh.shape["model"]) > 1:
            # model-sharded lattice: commit the initial params to their
            # param_spec placement so the very first dispatch — not just the
            # constrained carry — holds only D/|model| columns per device
            from repro.launch.sharding import param_spec  # late: launch↔sim

            def place_leaf(leaf):
                sh = NamedSharding(mesh, param_spec(np.shape(leaf), mesh))
                if multihost:
                    return shard_to_global(leaf, sh)
                return jax.device_put(jnp.asarray(leaf), sh)

            params0 = jax.tree.map(place_leaf, params0)
    else:
        def place(c):
            return jnp.asarray(c)

    cells_b = [place(c) for c in cells]
    n_padded = cells[0].size

    grid_shape = (len(spec.noise_powers), len(spec.alphas), len(spec.seeds))

    def _shape_flat(a) -> np.ndarray:
        """Fused flat order (A·P·B, T) → the (A, P, Nn, Na, Ns, T) grid
        (A == 1 when the algorithm axis isn't traced)."""
        return np.asarray(a).reshape(
            (len(algs), len(spec.policies)) + grid_shape + (spec.n_rounds,)
        )

    def _shape_stacked(a) -> np.ndarray:
        """Per-policy stack (P, A·B, T) → the (A, P, Nn, Na, Ns, T) grid."""
        shaped = np.asarray(a).reshape(
            (len(spec.policies), len(algs)) + grid_shape + (spec.n_rounds,)
        )
        return np.moveaxis(shaped, 1, 0)

    def one_engine(cfg: POFLConfig):
        return cached_engine(
            loss_fn, data, cfg,
            channel_cfg=channel_cfg,
            scenario=scenario,
            scenario_params=scenario_params,
            eval_fn=eval_fn,
            mesh=mesh,
            obs=obs,
        )

    def _emit_run(eng, warm: bool, tr0: int, co0: int, **fields) -> None:
        """One ``lattice`` JSONL event per engine dispatch — the raw material
        of the ``repro.obs.report`` warm-retrace gate."""
        emit(
            "lattice", "lattice.run",
            cells=n_real, n_rounds=spec.n_rounds, multihost=multihost,
            algorithms=len(algs), warm=warm,
            trace_delta=eng.n_lattice_traces - tr0,
            compile_delta=eng.n_compiles - co0,
            engine_compiles=eng.n_compiles,
            **fields,
        )

    def _grid_eval(ev, shape_fn) -> Any:
        """Reshape the flat model-task eval subtree (tasks.EvalRecord of
        (cells, T) leaves) to (A, P, Nn, Na, Ns, E) curves."""
        return type(ev)(
            *(shape_fn(np.asarray(a))[..., do_eval] for a in ev)
        )

    def _grid_health(h, shape_fn) -> Any:
        """Reshape the flat quarantine subtree (core.metrics.RoundHealth of
        (cells, T) leaves) to the (A, P, Nn, Na, Ns, T) grid."""
        return type(h)(*(shape_fn(np.asarray(a)) for a in h))

    def _grid_diag(tap_arrays, shape_fn) -> Any:
        """Reshape flat tap leaves to the (A, P, Nn, Na, Ns, T) grid."""
        from repro.core.metrics import RoundDiagnostics

        shaped = RoundDiagnostics(*(shape_fn(a) for a in tap_arrays))
        emit(
            "diag", "lattice.diagnostics",
            cells=n_real, n_rounds=spec.n_rounds,
            taps={
                f: np.mean(
                    getattr(shaped, f),
                    axis=tuple(range(getattr(shaped, f).ndim - 1)),
                ).tolist()
                for f in shaped._fields
            },
        )
        return shaped

    if fuse_policies:
        if traced_algs:
            noise_b, alpha_b, seed_b, policy_b, algorithm_b = cells_b
        else:
            noise_b, alpha_b, seed_b, policy_b = cells_b
            algorithm_b = None
        cfg = dataclasses.replace(
            base_cfg, policy=FUSED_POLICY, local_algorithm=base_alg,
            n_devices=data.n_devices,
        )
        eng = one_engine(cfg)
        warm, tr0, co0 = eng.n_lattice_traces > 0, eng.n_lattice_traces, eng.n_compiles
        with span(
            "lattice.sweep", cells=n_real, fused=True,
            policies=len(spec.policies), algorithms=len(algs),
            multihost=multihost,
        ):
            recs = eng.run_lattice_cells(
                params0, t_ints, do_eval, noise_b, alpha_b, seed_b,
                policy_b=policy_b, algorithm_b=algorithm_b,
            )
            if multihost:
                # drain the (collective-free) compute before the gather's single
                # collective program launches anywhere — overlapping launches are
                # what the CPU gloo runtime cannot be trusted with
                jax.block_until_ready(recs)
            # single stream-out: device → host exactly once for the whole
            # lattice, dropping any dead padding cells
            recs = gather_records(recs, mesh) if multihost else jax.device_get(recs)
        _emit_run(eng, warm, tr0, co0, fused=True)
        recs = jax.tree.map(lambda a: a[:n_real], recs)

        def gather(field: str, eval_only: bool) -> np.ndarray:
            # (A·P·B, T) flat, algorithm-major then policy-major
            stacked = _shape_flat(getattr(recs, field))
            return stacked[..., do_eval] if eval_only else stacked

        diag = None if recs.diag is None else _grid_diag(list(recs.diag), _shape_flat)
        ev = None if recs.eval is None else _grid_eval(recs.eval, _shape_flat)
        health = (
            None if recs.health is None
            else _grid_health(recs.health, _shape_flat)
        )
        return _assemble_records(
            spec, algs, gather, eval_rounds, diag=diag, eval=ev, health=health
        )

    if traced_algs:
        noise_b, alpha_b, seed_b, algorithm_b = cells_b
    else:
        noise_b, alpha_b, seed_b = cells_b
        algorithm_b = None
    per_policy = []
    with span(
        "lattice.sweep", cells=n_real, fused=False,
        policies=len(spec.policies), algorithms=len(algs),
        multihost=multihost,
    ):
        for policy in spec.policies:
            # same traced-dispatch cell program, constant policy axis — one
            # (smaller) compile per policy, per-cell values bit-identical to the
            # fused program's lanes
            policy_b = place(
                np.full((n_padded,), scheduling.policy_id(policy), np.int32)
            )
            cfg = dataclasses.replace(
                base_cfg, policy=policy, local_algorithm=base_alg,
                n_devices=data.n_devices,
            )
            eng = one_engine(cfg)
            warm, tr0, co0 = (
                eng.n_lattice_traces > 0, eng.n_lattice_traces, eng.n_compiles
            )
            recs = eng.run_lattice_cells(
                params0, t_ints, do_eval, noise_b, alpha_b, seed_b,
                policy_b=policy_b, algorithm_b=algorithm_b,
            )
            _emit_run(eng, warm, tr0, co0, fused=False, policy=policy)
            if multihost:
                jax.block_until_ready(recs)
            per_policy.append(recs)  # stays on device until the final stream-out

        # single stream-out: device → host exactly once for the whole lattice,
        # dropping any dead padding cells (multi-host: a tiled allgather first —
        # no process can address the other hosts' record shards directly)
        per_policy = (
            gather_records(per_policy, mesh) if multihost else jax.device_get(per_policy)
        )
    per_policy = jax.tree.map(lambda a: a[:n_real], per_policy)

    def gather(field: str, eval_only: bool) -> np.ndarray:
        stacked = np.stack([getattr(r, field) for r in per_policy])  # (P, A·B, T)
        stacked = _shape_stacked(stacked)
        return stacked[..., do_eval] if eval_only else stacked

    diag = None
    if per_policy and per_policy[0].diag is not None:
        diag = _grid_diag([
            np.stack([np.asarray(getattr(r.diag, f)) for r in per_policy])
            for f in per_policy[0].diag._fields
        ], _shape_stacked)
    ev = None
    if per_policy and per_policy[0].eval is not None:
        first_ev = per_policy[0].eval
        ev = _grid_eval(
            type(first_ev)(*(
                np.stack([np.asarray(getattr(r.eval, f)) for r in per_policy])
                for f in first_ev._fields
            )),
            _shape_stacked,
        )
    health = None
    if per_policy and per_policy[0].health is not None:
        first_h = per_policy[0].health
        health = type(first_h)(*(
            _shape_stacked(
                np.stack([np.asarray(getattr(r.health, f)) for r in per_policy])
            )
            for f in first_h._fields
        ))
    return _assemble_records(
        spec, algs, gather, eval_rounds, diag=diag, eval=ev, health=health
    )


def _concat_algorithms(
    algs: tuple[str, ...], per_alg: list[LatticeRecords]
) -> LatticeRecords:
    """Stitch per-algorithm (1, P, ...) records back into one (A, P, ...)
    lattice — the ``fuse_algorithms=False`` assembly."""
    first = per_alg[0]
    cat = {
        f: np.concatenate([np.asarray(getattr(r, f)) for r in per_alg], axis=0)
        for f in ("e_com", "e_var", "grad_norm", "n_scheduled", "loss", "acc")
    }
    diag = None
    if first.diag is not None:
        diag = type(first.diag)(*(
            np.concatenate([np.asarray(getattr(r.diag, f)) for r in per_alg], axis=0)
            for f in first.diag._fields
        ))
    ev = None
    if first.eval is not None:
        ev = type(first.eval)(*(
            np.concatenate([np.asarray(getattr(r.eval, f)) for r in per_alg], axis=0)
            for f in first.eval._fields
        ))
    health = None
    if first.health is not None:
        health = type(first.health)(*(
            np.concatenate(
                [np.asarray(getattr(r.health, f)) for r in per_alg], axis=0
            )
            for f in first.health._fields
        ))
    return LatticeRecords(
        axes={**first.axes, "algorithm": list(algs)},
        eval_rounds=first.eval_rounds,
        diag=diag,
        eval=ev,
        health=health,
        **cat,
    )


def _assemble_records(
    spec: LatticeSpec, algs, gather, eval_rounds, diag=None, eval=None,
    health=None,
) -> LatticeRecords:
    return LatticeRecords(
        axes={
            "algorithm": list(algs),
            "policy": list(spec.policies),
            "noise_power": list(spec.noise_powers),
            "alpha": list(spec.alphas),
            "seed": list(spec.seeds),
        },
        e_com=gather("e_com", False),
        e_var=gather("e_var", False),
        grad_norm=gather("grad_norm", False),
        n_scheduled=gather("n_scheduled", False),
        loss=gather("loss", True),
        acc=gather("acc", True),
        eval_rounds=eval_rounds,
        diag=diag,
        eval=eval,
        health=health,
    )


def fused_flat_grid(
    spec: LatticeSpec,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray | None]:
    """The policy-fused flattened cell grid of ``spec`` as
    ``(noise, alpha, seed, policy_id, algorithm_id-or-None)`` flat (B,)
    arrays — EXACTLY the fused order ``run_lattice`` vmaps over (algorithm-
    major, then policy-major, then noise × alpha × seed), so a flat index
    reshapes to the (A, P, Nn, Na, Ns) grid with a plain ``reshape``.
    ``algorithm_id`` is ``None`` for single-algorithm specs (the static-
    dispatch path). ``sim.resilience`` shards THIS order across workers.
    """
    for a in spec.algorithms:
        local_update.algorithm_id(a)
    grid_axes = [
        np.asarray(spec.noise_powers, np.float32),
        np.asarray(spec.alphas, np.float32),
        np.asarray(spec.seeds, np.int32),
    ]
    pol_ids = np.asarray(
        [scheduling.policy_id(p) for p in spec.policies], np.int32
    )
    if len(spec.algorithms) > 1:
        alg_ids = np.asarray(
            [local_update.algorithm_id(a) for a in spec.algorithms], np.int32
        )
        grid_al, grid_p, grid_n, grid_a, grid_s = np.meshgrid(
            alg_ids, pol_ids, *grid_axes, indexing="ij"
        )
        return (
            grid_n.ravel(), grid_a.ravel(), grid_s.ravel(),
            grid_p.ravel(), grid_al.ravel(),
        )
    grid_p, grid_n, grid_a, grid_s = np.meshgrid(
        pol_ids, *grid_axes, indexing="ij"
    )
    return grid_n.ravel(), grid_a.ravel(), grid_s.ravel(), grid_p.ravel(), None


def assemble_flat_fused(
    spec: LatticeSpec, flat_records, do_eval: np.ndarray,
    eval_rounds: np.ndarray,
) -> LatticeRecords:
    """Assemble a flat fused-order record pytree into :class:`LatticeRecords`.

    ``flat_records`` is a host-side ``RoundRecord`` whose leaves are
    ``(B, T)`` arrays in :func:`fused_flat_grid` order (B = ``spec.n_cells``)
    — what the chunked engine programs of ``sim.resilience`` accumulate, and
    what a supervisor reassembles from per-worker shards. The reshape (and
    the optional diag/eval/health subtree handling) matches ``run_lattice``'s
    fused path exactly.
    """
    algs = tuple(spec.algorithms)
    grid_shape = (len(spec.noise_powers), len(spec.alphas), len(spec.seeds))

    def shape_flat(a) -> np.ndarray:
        return np.asarray(a).reshape(
            (len(algs), len(spec.policies)) + grid_shape + (spec.n_rounds,)
        )

    def gather(field: str, eval_only: bool) -> np.ndarray:
        stacked = shape_flat(getattr(flat_records, field))
        return stacked[..., do_eval] if eval_only else stacked

    diag = None
    if flat_records.diag is not None:
        diag = type(flat_records.diag)(
            *(shape_flat(a) for a in flat_records.diag)
        )
    ev = None
    if flat_records.eval is not None:
        ev = type(flat_records.eval)(
            *(shape_flat(np.asarray(a))[..., do_eval] for a in flat_records.eval)
        )
    health = None
    if flat_records.health is not None:
        health = type(flat_records.health)(
            *(shape_flat(a) for a in flat_records.health)
        )
    return _assemble_records(
        spec, algs, gather, eval_rounds, diag=diag, eval=ev, health=health
    )
