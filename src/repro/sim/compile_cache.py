"""Durable XLA compiles: JAX's persistent compilation cache, wired from one
env/arg contract.

The lattice's dominant cold-call cost is the XLA compile (BENCH_sim.json's
``compile_seconds``), and it is identical across processes for identical
programs — so paying it once per *machine* (or once per CI cache key)
instead of once per process is pure win. This module turns JAX's persistent
compilation cache on from the ``REPRO_COMPILE_CACHE`` environment variable
(or an explicit path):

    REPRO_COMPILE_CACHE=~/.cache/repro-xla python -m benchmarks.run
    REPRO_COMPILE_CACHE=.jax-cache python -m pytest tests/test_lattice_sharded.py

Callers: ``benchmarks/run.py``, ``examples/sim_lattice.py``, the
``repro.launch.distributed`` worker entrypoints (the env var is inherited by
every spawned worker), and ``tests/conftest.py`` (so CI can warm-run suites
against an ``actions/cache``'d directory). All of them call
:func:`enable_compile_cache` unconditionally — it is a no-op returning None
when the contract is unset.

Hit accounting: :func:`enable_compile_cache` registers a
``jax.monitoring`` listener counting the ``/jax/compilation_cache/*``
events, exposed by :func:`persistent_cache_counters` — within one process a
program compiled earlier in the SAME process hits jax's in-memory caches
first, so persistent hits are expected on *fresh* processes (the CI
assertion runs pytest twice and requires hits > 0 on the second run).

Config-flag compat: everything is applied via ``jax.config.update`` guarded
for absent flags (jax 0.4.37 has all of them; older jaxes degrade to
whichever subset exists). Must run before the first compile to catch it,
but is safe (and still effective for later compiles) at any point.
"""
from __future__ import annotations

import os
from typing import Any

import jax

from repro.obs.registry import counter_add, metric_value

ENV_CACHE_DIR = "REPRO_COMPILE_CACHE"

# hit/miss counts live in the obs registry under ``compile_cache.`` —
# PROCESS-LIFETIME counters (the CI warm-run guard reads them at session
# end), so nothing may reset that namespace mid-process
_LISTENER_INSTALLED = False


def _count_cache_events(event: str, **kwargs: Any) -> None:
    if event == "/jax/compilation_cache/cache_hits":
        counter_add("compile_cache.hits")
    elif event == "/jax/compilation_cache/cache_misses":
        counter_add("compile_cache.misses")


def _install_listener() -> None:
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return
    try:
        from jax import monitoring  # public since jax 0.4.x
    except ImportError:  # pragma: no cover - very old jax
        from jax._src import monitoring
    monitoring.register_event_listener(_count_cache_events)
    _LISTENER_INSTALLED = True


def enable_compile_cache(path: str | None = None) -> str | None:
    """Enable the persistent compilation cache; returns the cache dir or None.

    ``path`` defaults to ``$REPRO_COMPILE_CACHE``; when neither is set this
    is a no-op (None). The directory is created, every-compile persistence is
    forced (min-entry-size/min-compile-time floors dropped — the lattice's
    many small sub-programs should all hit on the next process), and the
    hit/miss listener is installed.
    """
    path = path or os.environ.get(ENV_CACHE_DIR) or None
    if not path:
        return None
    path = os.path.abspath(os.path.expanduser(path))
    os.makedirs(path, exist_ok=True)
    _apply_config("jax_compilation_cache_dir", path)
    _apply_config("jax_persistent_cache_min_entry_size_bytes", -1)
    _apply_config("jax_persistent_cache_min_compile_time_secs", 0.0)
    _install_listener()
    return path


def _apply_config(name: str, value) -> None:
    try:
        jax.config.update(name, value)
    except (AttributeError, ValueError):  # pragma: no cover - older jax
        pass


def persistent_cache_counters() -> dict:
    """This process's persistent-cache hit/miss counts (since enable).

    Thin shim over the obs registry (``compile_cache.hits`` / ``.misses``).
    """
    return {
        "hits": int(metric_value("compile_cache.hits")),
        "misses": int(metric_value("compile_cache.misses")),
    }


def cache_dir_entries(path: str | None = None) -> int:
    """Number of cache payload files in the (env-contract) cache directory —
    0 for unset/missing. jax writes one ``*-cache`` payload (plus an
    ``-atime`` sidecar under LRU budgeting) per compiled program."""
    path = path or os.environ.get(ENV_CACHE_DIR) or None
    if not path or not os.path.isdir(path):
        return 0
    return sum(1 for n in os.listdir(path) if not n.endswith("-atime"))
