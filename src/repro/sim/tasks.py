"""Real-model federated tasks for the lattice engine (paper Sec. V-A).

``models/small.py`` implements the paper's two evaluation models — 784-dim
logistic regression (convex) and the 4-conv CNN (non-convex, ~2.6×10⁵
raveled params) — as pure (init, loss) pytree triples. This module wires
them into the simulation stack as first-class **tasks**:

  * :class:`ModelTask` — bundles the dict-pytree ``params0``, the
    jax-traceable ``loss_fn(params, x, y)`` closure (the exact signature
    ``core.local_update``'s K-step local SGD consumes; per-device minibatch
    draws and the flat-D ravel/unravel happen inside the round pipeline),
    the partitioned train shards (:class:`~repro.core.pofl.DeviceData`), and
    a :class:`TaskEval`. ``ravel``/``unravel`` expose the
    ``jax.flatten_util.ravel_pytree`` bijection between the pytree and the
    engine's flat-D vector (``dim`` is its length), and ``flat_loss_fn``
    is the same loss over the flat vector for code that works in D-space.
  * :class:`TaskEval` — a *traceable* eval closure over a fixed test set.
    Calling it returns the legacy ``(loss, acc)`` pair (drop-in for every
    ``eval_fn`` seam: ``SimEngine``, ``run_pofl``'s host-side eval,
    ``run_lattice``); :meth:`TaskEval.record` returns the structured
    :class:`EvalRecord` the engine stacks into the ``RoundRecord.eval`` /
    ``LatticeRecords.eval`` subtree. Pad discipline: ``n_valid`` marks the
    true-sample prefix of a padded test set, and BOTH loss and accuracy are
    computed over exactly those rows — pad rows (e.g. the wrap-padding of
    ``data.partition``'s sized shards) never count (the same valid-prefix
    contract as ``local_update.draw_minibatch``).
  * :func:`make_model_task` — the memoized factory: repeat calls with the
    same arguments return the SAME task object, so ``loss_fn``/``eval_fn``
    identity — which keys :func:`~repro.sim.engine.cached_engine` — is
    stable and a repeat sweep over a rebuilt task re-traces ZERO times.

Record contract (the PR-6 ``diag=None`` trick, third application): a lattice
run whose ``eval_fn`` is a :class:`TaskEval` grows an ``eval`` subtree on
``RoundRecord``/``LatticeRecords``; any other eval_fn (or none) leaves the
field ``None``, which flattens to an EMPTY pytree — the compiled program and
every pre-existing pinned trajectory stay bitwise unchanged.

Datasets are the seeded synthetic MNIST-/CIFAR-shaped generators from
``repro.data.synthetic`` (offline container — CI needs no downloads).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.pofl import DeviceData
from repro.data.synthetic import make_classification_dataset
from repro.models import small
from repro.sim.scenario import PARTITIONS, make_partition

# task registry: name -> (dataset kind, init/loss/logits triple). Append-only
# (like the policy/algorithm tables): positions and names are forever.
TASKS = ("logreg", "cnn")


class EvalRecord(NamedTuple):
    """One structured eval point (the ``RoundRecord.eval`` subtree leaves).

    Scalars inside the engine's scan; the lattice stacks them to
    ``(A, P, Nn, Na, Ns, E)`` arrays on ``LatticeRecords.eval``. ``n_correct``
    is the raw correct-prediction count over the VALID test rows — alongside
    ``acc`` it pins the denominator, so a pad-row leak (counting padded test
    rows) is visible as ``acc != n_correct / n_valid``.
    """

    loss: jnp.ndarray       # mean NLL over the valid test rows
    acc: jnp.ndarray        # fraction of valid rows predicted correctly
    n_correct: jnp.ndarray  # correct predictions among the valid rows


def zero_eval_record() -> EvalRecord:
    """The inactive-branch / not-an-eval-round record (all-zero scalars) —
    must mirror :meth:`TaskEval.record`'s structure exactly."""
    return EvalRecord(*(jnp.zeros((), jnp.float32) for _ in EvalRecord._fields))


class TaskEval:
    """Traceable pad-masked classification eval over a fixed test set.

    Args:
      logits_fn: ``(params, x) -> (B, n_classes)`` logits (jax-traceable).
      x_test, y_test: the full (possibly padded) test arrays.
      n_valid: number of TRUE test rows (the valid prefix); rows at and past
        ``n_valid`` are padding and are excluded from loss, accuracy, and the
        correct count. ``None`` means the whole set is valid.
      batch: cap on rows evaluated (static slice, like the historical
        ``small.make_eval_fn``); the effective row count is
        ``min(batch, n_valid, len(y_test))``.

    ``__call__`` returns the legacy ``(loss, acc)`` pair; :meth:`record`
    returns the full :class:`EvalRecord`. Instances hash by identity, so a
    ``TaskEval`` is a valid ``cached_engine`` key component (task identity).
    """

    def __init__(
        self,
        logits_fn: Callable,
        x_test,
        y_test,
        n_valid: int | None = None,
        batch: int = 1000,
    ):
        self.logits_fn = logits_fn
        self.x_test = jnp.asarray(x_test)
        self.y_test = jnp.asarray(y_test)
        n_rows = int(self.y_test.shape[0])
        n_valid = n_rows if n_valid is None else int(n_valid)
        if not 0 < n_valid <= n_rows:
            raise ValueError(
                f"n_valid must be in [1, {n_rows}] (got {n_valid})"
            )
        # static: the pad contract is valid-PREFIX (same as DeviceData), so
        # the masked mean is exactly a static slice — no traced select ops
        self.n_valid = min(n_valid, int(batch))

    def record(self, params) -> EvalRecord:
        n = self.n_valid
        x, y = self.x_test[:n], self.y_test[:n]
        logits = self.logits_fn(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        n_correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        )
        return EvalRecord(
            loss=jnp.asarray(loss, jnp.float32),
            acc=n_correct / jnp.float32(n),
            n_correct=n_correct,
        )

    def __call__(self, params) -> tuple[jnp.ndarray, jnp.ndarray]:
        rec = self.record(params)
        return rec.loss, rec.acc


@dataclasses.dataclass(frozen=True, eq=False)
class ModelTask:
    """A real-model federated task: everything one ``run_lattice`` /
    ``run_pofl`` call needs, plus the pytree ↔ flat-D bijection.

    ``eq=False``: tasks compare (and hash) by identity — the engine cache
    keys on the ``loss_fn``/``eval`` objects this task carries, and
    :func:`make_model_task` memoizes construction so equal arguments yield
    the identical object.
    """

    name: str                 # TASKS entry ("logreg" | "cnn")
    loss_fn: Callable         # (params pytree, x, y) -> scalar mean NLL
    logits_fn: Callable       # (params pytree, x) -> logits
    params0: Any              # dict-pytree initial parameters
    data: DeviceData          # partitioned (possibly padded) train shards
    eval: TaskEval            # pad-masked test-set eval
    dim: int                  # raveled flat model dimension D
    unravel: Callable         # flat (D,) -> params pytree

    def ravel(self, params) -> jnp.ndarray:
        """Params pytree -> the engine's flat (D,) float vector."""
        return ravel_pytree(params)[0]

    def flat_loss_fn(self) -> Callable:
        """The same loss over a flat (D,) weight vector — the D-space view
        ``core.local_update`` uses internally for per-device weights."""

        def loss(flat_w, x, y):
            return self.loss_fn(self.unravel(flat_w), x, y)

        return loss


def _build_model_task(
    kind: str,
    n_devices: int,
    partition: str,
    n_train: int,
    n_test: int,
    seed: int,
    dim: int | None,
    beta: float,
    classes_per_device: int,
    channel_bias: float,
) -> ModelTask:
    if kind not in TASKS:
        raise ValueError(f"unknown task {kind!r}; known: {TASKS}")
    key = jax.random.PRNGKey(seed)
    k_train, k_test, k_init = jax.random.split(key, 3)
    ds = "mnist_like" if kind == "logreg" else "cifar_like"
    ds_kw: dict = {"dim": dim} if (dim is not None and kind == "logreg") else {}
    if dim is not None and kind == "cnn":
        raise ValueError("dim override only supported for the logreg task")
    if channel_bias:
        if kind != "cnn":
            raise ValueError("channel_bias only applies to the cnn task")
        ds_kw["channel_bias"] = channel_bias
    x_tr, y_tr = make_classification_dataset(ds, n_train, k_train, **ds_kw)
    x_te, y_te = make_classification_dataset(ds, n_test, k_test, **ds_kw)

    part_kw: dict = {}
    if partition == "shards":
        part_kw["shards_per_device"] = classes_per_device
    elif partition.startswith("dirichlet"):
        part_kw["beta"] = beta
    data = make_partition(
        partition, np.asarray(x_tr), np.asarray(y_tr), n_devices,
        seed=seed, **part_kw,
    )

    if kind == "logreg":
        params0 = small.init_logreg(k_init, dim=int(x_tr.shape[-1]))
        loss_fn, logits_fn = small.logreg_loss, small.logreg_logits
    else:
        params0 = small.init_cnn(k_init)
        loss_fn, logits_fn = small.cnn_loss, small.cnn_logits

    flat, unravel = ravel_pytree(params0)
    return ModelTask(
        name=kind,
        loss_fn=loss_fn,
        logits_fn=logits_fn,
        params0=params0,
        data=data,
        eval=TaskEval(logits_fn, x_te, y_te, batch=n_test),
        dim=int(flat.size),
        unravel=unravel,
    )


@functools.lru_cache(maxsize=16)
def make_model_task(
    kind: str = "logreg",
    n_devices: int = 8,
    partition: str = "shards",
    n_train: int = 1024,
    n_test: int = 256,
    seed: int = 0,
    dim: int | None = None,
    beta: float = 0.4,
    classes_per_device: int = 2,
    channel_bias: float = 0.0,
) -> ModelTask:
    """Build (or return the memoized) :class:`ModelTask`.

    Args:
      kind: ``"logreg"`` (MNIST-shaped, convex) or ``"cnn"`` (CIFAR-shaped
        4-conv CNN, non-convex, D ≈ 2.6×10⁵).
      n_devices: federated devices to partition the train set over.
      partition: any ``sim.scenario.PARTITIONS`` name; the sized/mixed
        Dirichlet presets produce PADDED heterogeneous shards
        (``DeviceData.n_samples``) over the image-shaped features.
      n_train, n_test: synthetic train/test sample counts.
      seed: data draw + init seed (class prototypes stay fixed by the
        dataset's ``proto_seed``, so train/test share one distribution).
      dim: logreg-only flat feature-dimension override (the D-scaling axis).
      beta: Dirichlet concentration for the ``dirichlet*`` partitions.
      classes_per_device: label shards per device for ``"shards"``.
      channel_bias: cnn-only per-class channel offset strength (see
        ``data.synthetic.make_classification_dataset``) — gives the GAP-CNN
        a pooling-survivable class signal so few-round runs show learning.

    Memoized on the full argument tuple: a repeat call is the SAME object,
    so engines cached against its ``loss_fn``/``eval`` are re-used (zero
    re-traces on repeat sweeps over a rebuilt task).
    """
    if partition not in PARTITIONS:
        raise ValueError(
            f"unknown partition {partition!r}; known: {PARTITIONS}"
        )
    return _build_model_task(
        kind, n_devices, partition, n_train, n_test, seed, dim, beta,
        classes_per_device, channel_bias,
    )
