"""Multi-host lattice plumbing: ``jax.distributed`` init + process-spanning
cell meshes + per-process shard feeding and record gathering.

PR 3 sharded the lattice's flattened cell axis over a *single-process* mesh;
this module is the process-spanning half of that story (and since the PR-5
policy-fused lattice the sharded cell axis spans POLICIES too — the whole
multi-policy spec is one program whose shard feed and record gather route
through here unchanged). Each participating process runs the SAME
``run_lattice`` call (SPMD — every process executes every compiled
dispatch), but only materializes / computes the shard of the padded cell
grid that lives on its addressable devices:

  * :func:`initialize_distributed` wires ``jax.distributed`` from explicit
    args or the ``REPRO_DIST_*`` env contract written by
    ``repro.launch.distributed`` (the local CPU launcher). On CPU it selects
    the ``gloo`` cross-process collectives implementation — the default
    (``none``) cannot run multiprocess computations at all.
  * :func:`make_global_cell_mesh` builds the 1-D ``("cells",)`` mesh over the
    GLOBAL device list (``jax.devices()`` spans every process after
    ``jax.distributed.initialize``); :func:`make_cell_mesh` stays the
    local-devices-only spelling.
  * :func:`shard_to_global` assembles a global ``jax.Array`` from the host
    copy of a cell-axis input: every process holds the full (deterministic)
    numpy grid, slices out its addressable shards via
    ``Sharding.addressable_devices_indices_map``, and stitches them with
    ``jax.make_array_from_single_device_arrays``.
  * :func:`gather_records` brings a pytree of cell-sharded outputs back to
    EVERY host as plain numpy through ONE replicating identity program (a
    single cross-process collective rendezvous per gather), so
    unpadding/reshaping stays ordinary host code and each host — host 0
    included, which is the one that persists results — returns identical
    :class:`~repro.sim.lattice.LatticeRecords`.

None of this touches jax device state at import time: ``initialize_distributed``
must run before the first backend query, so this module is import-safe from
anywhere (the launcher imports it before deciding whether to initialize).
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Any

import jax
import numpy as np

from repro.obs.spans import span
from repro.sim.engine import _mesh_key

ENV_COORDINATOR = "REPRO_DIST_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_DIST_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_DIST_PROCESS_ID"


@dataclasses.dataclass(frozen=True)
class DistributedConfig:
    """One process's view of the ``jax.distributed`` topology."""

    coordinator: str   # "host:port" of process 0's coordination service
    num_processes: int
    process_id: int


def distributed_env() -> DistributedConfig | None:
    """Read the ``REPRO_DIST_*`` env contract; ``None`` when not set.

    The contract is written by ``repro.launch.distributed`` for every worker
    it spawns; real multi-host deployments (SLURM, k8s) can export the same
    three variables instead of passing explicit args.
    """
    names = (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID)
    values = [os.environ.get(n) for n in names]
    if not any(values):
        return None
    missing = [n for n, v in zip(names, values) if not v]
    if missing:
        raise ValueError(
            f"partial REPRO_DIST_* env contract: missing {missing}; a "
            f"distributed worker must export all of {list(names)}"
        )
    return DistributedConfig(
        coordinator=values[0],
        num_processes=int(values[1]),
        process_id=int(values[2]),
    )


_INITIALIZED = False


def initialize_distributed(cfg: DistributedConfig | None = None) -> bool:
    """Initialize ``jax.distributed`` from ``cfg`` or the env contract.

    Idempotent; a no-op (returning False) when neither names a multi-process
    topology — so single-process callers can call it unconditionally. Must
    run before the first jax backend query (device counts lock at backend
    init). Returns True when this process is part of a multi-process run.

    On CPU the cross-process collective implementation defaults to ``none``,
    which raises "Multiprocess computations aren't implemented on the CPU
    backend" at dispatch — so we switch it to ``gloo`` (shipped in jaxlib)
    before the backend exists. Guarded by ``getattr``-style try/except for
    jax versions that predate the flag.
    """
    global _INITIALIZED
    cfg = cfg or distributed_env()
    if cfg is None or cfg.num_processes <= 1:
        return _INITIALIZED
    if not _INITIALIZED:
        try:
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except (AttributeError, ValueError):  # pragma: no cover - old jax
            pass
        # bound the barrier wait: a half-formed topology (a peer crashed
        # before joining) must die loudly, not hang the worker forever
        try:
            jax.distributed.initialize(
                coordinator_address=cfg.coordinator,
                num_processes=cfg.num_processes,
                process_id=cfg.process_id,
                initialization_timeout=120,
            )
        except TypeError:  # pragma: no cover - jax without the kwarg
            jax.distributed.initialize(
                coordinator_address=cfg.coordinator,
                num_processes=cfg.num_processes,
                process_id=cfg.process_id,
            )
        _INITIALIZED = True
    return True


def cells_mesh_over(devices, n_devices: int | None, hint: str) -> jax.sharding.Mesh:
    """Shared constructor behind ``make_cell_mesh`` (local devices) and
    :func:`make_global_cell_mesh` (global devices): validate the count and
    build the 1-D ``("cells",)`` mesh. ``hint`` finishes the error message
    with the scope-appropriate remedy."""
    n = len(devices) if n_devices is None else n_devices
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"mesh wants {n} devices but only {len(devices)} are visible {hint}"
        )
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("cells",))


def make_global_cell_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """A 1-D ``("cells",)`` mesh over the first ``n_devices`` GLOBAL devices.

    After ``initialize_distributed`` the global device list spans every
    process, so the returned mesh does too; in a single-process run this is
    exactly ``make_cell_mesh``. ``None`` takes every global device.
    """
    return cells_mesh_over(
        jax.devices(), n_devices,
        hint=f"across {jax.process_count()} process(es)",
    )


def cell_model_mesh_over(
    devices, cells: int | None, model: int, hint: str
) -> jax.sharding.Mesh:
    """Shared constructor behind the 2-D ``("cells", "model")`` meshes
    (``sim.lattice.make_cell_model_mesh`` over local devices,
    :func:`make_global_cell_model_mesh` over global ones): validate counts
    and reshape the flat device list cells-major, so the first ``model``
    devices form cell-shard 0 — under ``jax.distributed`` a cell's model
    group stays within one process whenever ``model`` divides the per-process
    device count. ``cells=None`` takes every full group of ``model``
    devices."""
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if cells is None:
        cells = len(devices) // model
    n = cells * model
    if not (1 <= cells and 1 <= n <= len(devices)):
        raise ValueError(
            f"mesh wants {cells}x{model} = {n} devices but only "
            f"{len(devices)} are visible {hint}"
        )
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(cells, model), ("cells", "model")
    )


def make_global_cell_model_mesh(
    cells: int | None = None, model: int = 1
) -> jax.sharding.Mesh:
    """A 2-D ``("cells", "model")`` mesh over the GLOBAL device list — the
    process-spanning counterpart of ``sim.lattice.make_cell_model_mesh``."""
    return cell_model_mesh_over(
        jax.devices(), cells, model,
        hint=f"across {jax.process_count()} process(es)",
    )


def mesh_process_span(mesh) -> tuple[int, ...]:
    """Sorted process indices whose devices participate in ``mesh``."""
    return tuple(sorted({d.process_index for d in np.ravel(np.asarray(mesh.devices))}))


def mesh_spans_processes(mesh) -> bool:
    """True when ``mesh`` holds devices from more than one process."""
    return mesh is not None and len(mesh_process_span(mesh)) > 1


def shard_to_global(host_arr, sharding: jax.sharding.NamedSharding) -> jax.Array:
    """Assemble a global array from this process's addressable shards.

    Every process passes the SAME full host array (the cell grids are built
    deterministically from the spec on every host); each only ``device_put``s
    the slices its own devices own, and
    ``jax.make_array_from_single_device_arrays`` stitches them into one
    global array with ``sharding``. Works unchanged in a single process
    (where it is just a sliced ``device_put``).
    """
    host_arr = np.asarray(host_arr)
    index_map = sharding.addressable_devices_indices_map(host_arr.shape)
    shards = [
        jax.device_put(host_arr[index], device)
        for device, index in index_map.items()
    ]
    return jax.make_array_from_single_device_arrays(
        host_arr.shape, sharding, shards
    )


# bounded LRU, same rationale as the engine cache: entries pin mesh/device
# state and a compiled executable, so unbounded growth across successive
# distinct meshes would leak both
_GATHER_JITS: "OrderedDict[tuple, Any]" = OrderedDict()
_GATHER_JITS_MAX = 8


def _identity(leaves):
    return leaves


def gather_records(tree, mesh=None):
    """Gather a pytree of cell-sharded global arrays to EVERY host as numpy.

    Multi-process gathers replicate ALL leaves through ONE jitted identity
    program whose ``out_shardings`` are fully replicated over ``mesh`` — a
    single cross-process rendezvous per gather. (One collective launch per
    leaf — the ``multihost_utils.process_allgather`` spelling — proved racy
    on the CPU gloo runtime: back-to-back collective programs intermittently
    interleaved across processes, corrupting record buffers or deadlocking.)
    The leaves are drained with ``block_until_ready`` first, so no compute
    dispatch is still in flight anywhere when the collective starts. All
    hosts return identical values — host 0 is merely the one expected to
    persist them. Single-process: a plain ``device_get``.
    """
    if jax.process_count() == 1:
        return jax.device_get(tree)
    if mesh is None:
        raise ValueError("multi-process gather_records requires the cell mesh")
    from jax.sharding import NamedSharding, PartitionSpec

    leaves, treedef = jax.tree.flatten(tree)
    jax.block_until_ready(leaves)
    key = (_mesh_key(mesh), len(leaves))
    gather = _GATHER_JITS.get(key)
    if gather is None:
        gather = _GATHER_JITS[key] = jax.jit(
            _identity,
            out_shardings=[NamedSharding(mesh, PartitionSpec())] * len(leaves),
        )
        while len(_GATHER_JITS) > _GATHER_JITS_MAX:
            _GATHER_JITS.popitem(last=False)
    else:
        _GATHER_JITS.move_to_end(key)
    with span("multihost.gather", leaves=len(leaves)):
        gathered = jax.block_until_ready(gather(leaves))
    return jax.tree.unflatten(
        treedef, [np.asarray(g.addressable_data(0)) for g in gathered]
    )
