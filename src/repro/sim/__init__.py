"""repro.sim — vectorized scenario-lattice simulation engine for PO-FL.

Three layers (see ROADMAP.md "sim" section):

  * :mod:`repro.sim.scenario` — stateful channel processes (static Rayleigh,
    Gauss–Markov fading, mobility, dropout, churn) and data-heterogeneity
    presets (iid / shards / dirichlet / dirichlet_sized / dirichlet_mixed)
    behind string registries.
  * :mod:`repro.sim.engine`   — the ``lax.scan``-over-rounds round engine
    with a donated carry; ``core.pofl.run_pofl`` is a wrapper over it.
  * :mod:`repro.sim.lattice`  — experiment-lattice specs
    (algorithms × policies × noise_powers × alphas × seeds [× n_devices])
    compiled into
    one vmapped+scanned program per (policy, shape) group, optionally
    sharded along the cell axis over a ``jax.sharding`` mesh
    (``run_lattice(..., mesh=...)`` / :func:`make_cell_mesh`).
  * :mod:`repro.sim.tasks`    — real-model federated tasks
    (:func:`make_model_task`: the paper's logreg / 4-conv CNN over synthetic
    MNIST-/CIFAR-shaped data) with pad-masked :class:`TaskEval` evals that
    surface accuracy/loss curves as the ``LatticeRecords.eval`` subtree
    (OFF — an empty pytree — for any other eval_fn).
  * :mod:`repro.sim.multihost` — the process-spanning half of the lattice
    sharding story: ``jax.distributed`` init from the ``REPRO_DIST_*`` env
    contract (:func:`initialize_distributed`), global-device cell meshes
    (:func:`make_global_cell_mesh`), per-process shard feeding and record
    gathering. Driven locally by ``repro.launch.distributed``.
  * :mod:`repro.sim.resilience` — fault tolerance: checkpoint/resume of
    chunked lattice sweeps (:func:`run_lattice_checkpointed` — resume is
    bit-identical to uninterrupted), per-worker shard runs for the
    supervised launcher, and the deterministic ``REPRO_FAULT_*``
    fault-injection contract.
"""
from repro.sim.compile_cache import (
    enable_compile_cache,
    persistent_cache_counters,
)
from repro.sim.engine import (
    FUSED_ALGORITHM,
    FUSED_POLICY,
    SimEngine,
    SimState,
    cached_engine,
    engine_cache_stats,
    lattice_compile_stats,
    lattice_memory_stats,
    reset_engine_cache,
)
from repro.sim.lattice import (
    LatticeRecords,
    LatticeSpec,
    make_cell_mesh,
    make_cell_model_mesh,
    run_lattice,
)
from repro.sim.resilience import (
    CheckpointConfig,
    latest_checkpoint,
    merge_shards,
    run_lattice_checkpointed,
    run_worker_shard,
)
from repro.sim.multihost import (
    DistributedConfig,
    distributed_env,
    initialize_distributed,
    make_global_cell_mesh,
    make_global_cell_model_mesh,
    mesh_spans_processes,
)
from repro.sim.scenario import (
    CHANNEL_SCENARIOS,
    PARTITIONS,
    make_channel_process,
    make_partition,
)
from repro.sim.tasks import (
    TASKS,
    EvalRecord,
    ModelTask,
    TaskEval,
    make_model_task,
)

__all__ = [
    "CHANNEL_SCENARIOS",
    "CheckpointConfig",
    "DistributedConfig",
    "EvalRecord",
    "FUSED_ALGORITHM",
    "FUSED_POLICY",
    "LatticeRecords",
    "LatticeSpec",
    "ModelTask",
    "PARTITIONS",
    "SimEngine",
    "SimState",
    "TASKS",
    "TaskEval",
    "cached_engine",
    "distributed_env",
    "enable_compile_cache",
    "engine_cache_stats",
    "initialize_distributed",
    "lattice_compile_stats",
    "lattice_memory_stats",
    "latest_checkpoint",
    "make_cell_mesh",
    "make_cell_model_mesh",
    "make_channel_process",
    "make_global_cell_mesh",
    "make_global_cell_model_mesh",
    "make_model_task",
    "make_partition",
    "merge_shards",
    "mesh_spans_processes",
    "persistent_cache_counters",
    "reset_engine_cache",
    "run_lattice",
    "run_lattice_checkpointed",
    "run_worker_shard",
]
