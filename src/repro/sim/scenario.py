"""Scenario registry: channel processes + data-heterogeneity presets.

A *channel process* generalizes ``core.channel.ChannelState`` into a stateful
per-round process with the pure-functional interface

    proc.init(key)        -> state                  (pytree of arrays)
    proc.step(state, key) -> (state', h, avail)

where ``h`` is complex64 ``(n_devices,)`` fading and ``avail`` is a float 0/1
``(n_devices,)`` availability mask (all-ones except for dropout scenarios),
so it can live inside the engine's ``lax.scan`` carry and be vmapped across
lattice cells. All processes are frozen dataclasses (static config hashes
into the jit cache); all state is arrays. Processes with ``can_drop=False``
always return all-ones availability, and the engine skips the scheduling
masking entirely for them — keeping the static path bit-identical to the
seed ``run_pofl``. Availability only gates SCHEDULING (which Δ_i reach the
air), never local computation: under a multi-step ``cfg.local_steps`` round
(``core.local_update``), unavailable devices still advance their local
state (FedDyn h_i / SCAFFOLD c_i) that round — the Lemma-2 reweighting
``Δ_i/π_i`` stays unbiased over whatever deltas the devices hold
(tests/test_local_update.py).

Registered channel scenarios (``make_channel_process(name, cfg, **params)``):

  * ``static_rayleigh`` — the paper's Sec. V-A model and the seed repo's only
    scenario: path-loss gains drawn once from uniform distances, i.i.d.
    CN(0, g_i) block fading every round. Bit-identical to
    ``ChannelState.create(cfg, key)`` + ``.sample(key_t)``.
  * ``gauss_markov``    — first-order Gauss–Markov (Jakes-style) temporally
    correlated fading:  h_t = ρ·h_{t-1} + sqrt(1-ρ²)·CN(0, g).  Parameter
    ``corr`` = ρ ∈ [0, 1); stationary distribution CN(0, g) for any ρ
    (checked by tests/test_sim.py). ρ=0 recovers block fading in law.
  * ``mobility``        — time-varying path loss from a per-round Gaussian
    random walk on device distances, reflected into [d_min, d_max].
    Parameter ``speed`` = walk std in meters/round. Fading stays i.i.d.
    Rayleigh on top of the moving gains.
  * ``dropout``         — random device dropout/stragglers layered on any
    base scenario (default static_rayleigh): each round each device is
    independently unavailable with probability ``p_drop`` (crashed,
    straggling past the deadline, or out of coverage). Unavailable devices
    are excluded from scheduling for the round — the paper's Q-rule would
    otherwise chase them (Q_i ∝ 1/|h_i|), which is an artifact of its
    always-reachable assumption, not a meaningful policy comparison.
    Control-channel stats are still assumed known (idealization).
    Parameters: ``p_drop``, ``base`` (+ base-scenario params).
  * ``churn``           — arrival/departure population churn: availability
    is a per-device two-state Markov chain (present devices depart with
    prob ``p_depart``, absent ones (re)arrive with prob ``p_arrive``), so
    the online population *trends* over rounds — multi-round outages and
    re-joins — instead of flickering i.i.d. like ``dropout``. Stationary
    availability ``p_arrive/(p_arrive+p_depart)``; expected sojourns
    ``1/p_depart`` rounds online, ``1/p_arrive`` offline. Layered on any
    base scenario. Parameters: ``p_depart``, ``p_arrive``, ``init_online``
    (initial P(online); default = stationary), ``base`` (+ base params).

Data-heterogeneity presets (``make_partition(name, x, y, n_devices, ...)``):

  * ``iid``       — uniform random equal split (``partition_iid``).
  * ``shards``    — the paper's sort-by-label sharding
    (``partition_noniid_shards``; ``shards_per_device`` ≈ classes/device).
  * ``dirichlet`` — Dirichlet(β) label-proportion skew per device
    (``partition_dirichlet``; small β → near-single-class devices).
  * ``dirichlet_sized`` — Dirichlet(β) *shard-size* skew: unequal m_i drawn
    from Dir(β)·M, padded to a common length with ``DeviceData.n_samples``
    marking the valid prefixes (``partition_dirichlet_sized``) — the
    unbalanced-data regime of the Eq. 34/35/37 m_i/M weights.
  * ``dirichlet_mixed`` — label-skew × size-skew composed: Dir(β) class
    proportions over Dir(β_size)·M unequal shard sizes
    (``partition_dirichlet_mixed``) — the fully-heterogeneous regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.channel import (
    ChannelConfig,
    device_distances,
    path_loss,
    sample_channels,
)
from repro.data.partition import (
    partition_dirichlet,
    partition_dirichlet_mixed,
    partition_dirichlet_sized,
    partition_iid,
    partition_noniid_shards,
)

# --------------------------------------------------------------------------
# channel processes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StaticRayleigh:
    """Paper Sec. V-A: static path loss, i.i.d. Rayleigh block fading.

    Matches the seed ``ChannelState`` exactly: ``init`` consumes the key the
    way ``ChannelState.create`` does and ``step`` is ``ChannelState.sample``.
    """

    cfg: ChannelConfig
    can_drop = False

    def init(self, key: jax.Array):
        gains = path_loss(self.cfg, device_distances(self.cfg, key))
        return (gains,)

    def step(self, state, key: jax.Array):
        (gains,) = state
        h = sample_channels(self.cfg, gains, key)
        return state, h, jnp.ones_like(gains)


@dataclasses.dataclass(frozen=True)
class GaussMarkov:
    """First-order Gauss–Markov fading: h_t = ρ h_{t-1} + sqrt(1-ρ²) CN(0,g)."""

    cfg: ChannelConfig
    corr: float = 0.9  # ρ — per-round temporal correlation
    can_drop = False

    def init(self, key: jax.Array):
        k_dist, k_h0 = jax.random.split(key)
        gains = path_loss(self.cfg, device_distances(self.cfg, k_dist))
        h0 = sample_channels(self.cfg, gains, k_h0)  # stationary start
        return (gains, h0)

    def step(self, state, key: jax.Array):
        gains, h_prev = state
        innov = sample_channels(self.cfg, gains, key)
        h = self.corr * h_prev + jnp.sqrt(1.0 - self.corr**2) * innov
        return (gains, h), h, jnp.ones_like(gains)


@dataclasses.dataclass(frozen=True)
class Mobility:
    """Mobility-driven time-varying path loss (reflected random-walk distances)."""

    cfg: ChannelConfig
    speed: float = 1.0  # distance random-walk std [m/round]
    can_drop = False

    def init(self, key: jax.Array):
        return (device_distances(self.cfg, key),)

    def step(self, state, key: jax.Array):
        (dist,) = state
        k_walk, k_fade = jax.random.split(key)
        dist = dist + self.speed * jax.random.normal(k_walk, dist.shape)
        # reflect into [d_min, d_max] so devices never escape the cell
        lo, hi = self.cfg.d_min, self.cfg.d_max
        span = hi - lo
        dist = lo + jnp.abs(jnp.mod(dist - lo, 2.0 * span) - span)
        gains = path_loss(self.cfg, dist)
        h = sample_channels(self.cfg, gains, k_fade)
        return (dist,), h, jnp.ones_like(dist)


@dataclasses.dataclass(frozen=True)
class Dropout:
    """Random device dropout/stragglers on top of a base channel process.

    Each round each device is independently unavailable with probability
    ``p_drop``; the engine zeroes its scheduling probability for the round
    (the device can neither upload nor transmit). The base process keeps
    evolving underneath — a device that drops this round fades from the
    same trajectory next round.

    Rounds with fewer available devices than ``n_scheduled`` clamp the
    realized |S^t| to the available count (see
    ``scheduling.sample_without_replacement``); a round with *no* available
    device performs no update at all.
    """

    base: Any  # any channel process
    p_drop: float = 0.1
    can_drop = True

    def init(self, key: jax.Array):
        return self.base.init(key)

    def step(self, state, key: jax.Array):
        k_base, k_drop = jax.random.split(key)
        state, h, avail = self.base.step(state, k_base)
        up = 1.0 - jax.random.bernoulli(k_drop, self.p_drop, h.shape).astype(
            jnp.float32
        )
        return state, h, avail * up


@dataclasses.dataclass(frozen=True)
class Churn:
    """Arrival/departure population churn on top of a base channel process.

    Availability is a sticky per-device two-state Markov chain carried in the
    scan state: an online device goes offline (departs) with probability
    ``p_depart`` each round, an offline one (re)arrives with probability
    ``p_arrive`` — so availability *trends* (multi-round outages, gradual
    population drift) rather than flickering i.i.d. per round like
    :class:`Dropout`. The stationary online fraction is
    ``p_arrive / (p_arrive + p_depart)`` and the lag-1 autocorrelation of the
    availability indicator is ``1 - p_arrive - p_depart`` (checked by
    tests/test_sim.py). The base channel process keeps evolving underneath —
    a device that departs re-joins on its same fading trajectory.
    """

    cfg: ChannelConfig
    base: Any  # any channel process
    p_depart: float = 0.05
    p_arrive: float = 0.2
    init_online: float | None = None  # initial P(online); default stationary
    can_drop = True

    @property
    def _p0(self) -> float:
        if self.init_online is not None:
            return self.init_online
        return self.p_arrive / max(self.p_arrive + self.p_depart, 1e-12)

    def init(self, key: jax.Array):
        k_base, k_online = jax.random.split(key)
        online0 = jax.random.bernoulli(
            k_online, self._p0, (self.cfg.n_devices,)
        ).astype(jnp.float32)
        return (self.base.init(k_base), online0)

    def step(self, state, key: jax.Array):
        base_state, online = state
        k_base, k_flip = jax.random.split(key)
        base_state, h, base_avail = self.base.step(base_state, k_base)
        u = jax.random.uniform(k_flip, online.shape)
        stay = online * (u >= self.p_depart).astype(jnp.float32)
        arrive = (1.0 - online) * (u < self.p_arrive).astype(jnp.float32)
        online = stay + arrive
        return (base_state, online), h, base_avail * online


CHANNEL_SCENARIOS = (
    "static_rayleigh", "gauss_markov", "mobility", "dropout", "churn",
)


def make_channel_process(name: str, cfg: ChannelConfig, **params):
    """Instantiate a registered channel process over ``cfg``.

    ``dropout`` accepts ``base="..."`` plus the base scenario's params, e.g.
    ``make_channel_process("dropout", cfg, p_drop=0.2, base="gauss_markov",
    corr=0.95)``.
    """
    if name == "static_rayleigh":
        return StaticRayleigh(cfg, **params)
    if name == "gauss_markov":
        return GaussMarkov(cfg, **params)
    if name == "mobility":
        return Mobility(cfg, **params)
    if name == "dropout":
        base_name = params.pop("base", "static_rayleigh")
        p_drop = params.pop("p_drop", 0.1)
        base = make_channel_process(base_name, cfg, **params)
        return Dropout(base=base, p_drop=p_drop)
    if name == "churn":
        base_name = params.pop("base", "static_rayleigh")
        churn_kw = {
            k: params.pop(k)
            for k in ("p_depart", "p_arrive", "init_online")
            if k in params
        }
        base = make_channel_process(base_name, cfg, **params)
        return Churn(cfg=cfg, base=base, **churn_kw)
    raise ValueError(
        f"unknown channel scenario {name!r}; known: {CHANNEL_SCENARIOS}"
    )


# --------------------------------------------------------------------------
# data-heterogeneity presets
# --------------------------------------------------------------------------

PARTITIONS = ("iid", "shards", "dirichlet", "dirichlet_sized", "dirichlet_mixed")


def make_partition(name: str, features, labels, n_devices: int, seed: int = 0, **kw):
    """Partition (features, labels) into stacked per-device shards.

    ``features`` may be flat ``(n, d)`` vectors or image-shaped
    ``(n, H, W, C)`` batches (the CNN model task) — every preset indexes
    along axis 0 only, so the device axis stacks in front of whatever sample
    shape the model consumes. The sized/mixed Dirichlet presets wrap-pad
    shards to a common length and record true counts in
    ``DeviceData.n_samples`` (the valid-prefix contract minibatch draws and
    ``repro.sim.tasks.TaskEval`` both honor).
    """
    if name == "iid":
        return partition_iid(features, labels, n_devices, seed=seed)
    if name == "shards":
        return partition_noniid_shards(features, labels, n_devices, seed=seed, **kw)
    if name == "dirichlet":
        return partition_dirichlet(features, labels, n_devices, seed=seed, **kw)
    if name == "dirichlet_sized":
        return partition_dirichlet_sized(features, labels, n_devices, seed=seed, **kw)
    if name == "dirichlet_mixed":
        return partition_dirichlet_mixed(features, labels, n_devices, seed=seed, **kw)
    raise ValueError(f"unknown partition {name!r}; known: {PARTITIONS}")
