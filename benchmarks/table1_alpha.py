"""Table I — test accuracy of pofl vs α under varying noise power.

Paper claim validated: the optimal α grows as the channel degrades (larger
σ_z² → larger α emphasizes distortion reduction); at low noise small α
(importance-weighted) wins.

Both α and σ_z² are vmapped lattice axes: the entire table (α × σ_z² ×
trials) is one ``sim.lattice`` program.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import build_task, sweep_lattice

ALPHAS = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0)
NOISE_POWERS = (1e-9, 1e-10, 1e-11, 1e-12)


def main(full: bool = False):
    n_rounds = 100 if full else 30
    trials = 10 if full else 1
    task = build_task("mnist", n_train=6000 if full else 3000)
    alphas = ALPHAS if full else (0.001, 0.1, 10.0)
    noises = NOISE_POWERS if full else (1e-9, 1e-11)
    recs = sweep_lattice(
        task, policies=("pofl",), noise_powers=noises, alphas=alphas,
        n_rounds=n_rounds, n_trials=trials, eval_every=max(n_rounds // 5, 1),
    )
    results = {}
    print("\n== Table I (pofl accuracy, α × σ_z², MNIST) ==")
    print("  σ_z²      " + "".join(f"  α={a:<10g}" for a in alphas))
    for np_ in noises:
        row = {}
        for a in alphas:
            acc = recs.cell(policy="pofl", noise_power=np_, alpha=a)["acc"]
            row[a] = float(np.mean(np.max(acc, axis=-1)))
        results[np_] = row
        print(f"  {np_:8.0e}  " + "".join(f"  {row[a]:<12.4f}" for a in alphas))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
