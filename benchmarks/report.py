"""Render §Dry-run / §Roofline markdown tables from dryrun_results.jsonl,
plus the sim-lattice perf trajectory from ``BENCH_history.jsonl`` (one
appended record per ``python -m benchmarks.run``, stamped with git SHA and
timestamp — see ``benchmarks.run.append_history``)."""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import DEFAULT_JSON, load_records, roofline_terms
from benchmarks.run import HISTORY_PATH


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB/dev | HLO FLOPs (global) "
        "| coll GiB/dev | params |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} ({r.get('reason','')[:40]}…) | – | – | – | – |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{r['cost']['flops_global']:.2e} | "
            f"{r['collective_bytes_per_device']/2**30:.1f} | "
            f"{r['params']/1e9:.1f}B |"
        )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | bound | "
        "MODEL/HLO FLOPs |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        t = roofline_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['useful_ratio']:.1%} |"
        )
    return "\n".join(lines)


def load_history(path: str = HISTORY_PATH) -> list[dict]:
    """The appended bench trajectory, oldest first ([] when never run).
    Malformed lines (a torn append) are skipped, not raised."""
    if not os.path.exists(path):
        return []
    entries = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return entries


def history_table(entries) -> str:
    """Markdown trajectory of the sim-lattice bench across commits."""
    lines = [
        "| when | sha | backend | mesh | hosts | cells | steady cells/s | "
        "compile_s | n_compiles | speedup |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in entries:
        lines.append(
            f"| {str(e.get('timestamp', '?'))[:19]} | {e.get('git_sha', '?')} | "
            f"{e.get('backend', '?')} | {e.get('mesh_devices', '?')} | "
            f"{e.get('n_hosts', '?')} | {e.get('cells', '?')} | "
            f"{e.get('steady_cells_per_sec', '?')} | "
            f"{e.get('compile_seconds', '?')} | {e.get('n_compiles', '?')} | "
            f"{e.get('speedup', '?')} |"
        )
    return "\n".join(lines)


def _gate_key(e: dict) -> tuple:
    """The comparability key of a bench entry: only entries measuring the
    same workload on the same topology may be compared by the perf gate.
    ``mesh_shape``/``dim`` are absent in pre-2-D-mesh history — ``None``
    there matches only other legacy entries (likewise
    ``algorithms``/``local_steps``, absent before the local-update axis,
    and ``task``, absent before the model-task axis — a CNN entry never
    gate-compares against a logreg or legacy synthetic-task entry)."""
    algs = e.get("algorithms", None)
    return (
        e.get("backend"), e.get("mesh_shape", None),
        e.get("mesh_devices"), e.get("n_hosts"), e.get("dim", None),
        e.get("cells"), e.get("n_rounds"),
        tuple(algs) if algs is not None else None,
        e.get("local_steps", None),
        e.get("task", None),
    )


def gate_regression(
    entries: list[dict], max_regress: float = 0.2
) -> tuple[bool, str]:
    """Perf regression gate over the bench trajectory.

    Compares the LAST history entry's ``steady_cells_per_sec`` against the
    most recent PRIOR entry with the same :func:`_gate_key` (backend, mesh
    shape, host count, dim, sweep size, algorithms, local_steps, task).
    Returns ``(ok, message)`` — ok is
    False when throughput regressed by more than ``max_regress`` (fraction,
    default 20%). Passes trivially when there is no comparable prior entry
    (first run on a new configuration) or fewer than two entries total.
    """
    if len(entries) < 2:
        return True, "perf gate: <2 history entries, nothing to compare"
    last = entries[-1]
    cur = last.get("steady_cells_per_sec")
    if cur is None:
        return True, "perf gate: last entry has no steady_cells_per_sec"
    key = _gate_key(last)
    prior = next(
        (e for e in reversed(entries[:-1]) if _gate_key(e) == key), None
    )
    if prior is None or not prior.get("steady_cells_per_sec"):
        return True, (
            f"perf gate: no prior entry for {key}, passing trivially"
        )
    ref = float(prior["steady_cells_per_sec"])
    cur = float(cur)
    drop = (ref - cur) / ref
    msg = (
        f"perf gate: steady_cells_per_sec {cur:.3f} vs prior {ref:.3f} "
        f"({-drop:+.1%}; threshold -{max_regress:.0%}; key={key})"
    )
    return drop <= max_regress, msg


def main(path=DEFAULT_JSON, history_path=HISTORY_PATH):
    if os.path.exists(path):
        recs = sorted(
            load_records(path), key=lambda r: (r["arch"], r["shape"], r["mesh"])
        )
        print("### §Dry-run records\n")
        print(dryrun_table(recs))
        print("\n### §Roofline (single-pod 16×16)\n")
        print(roofline_table(recs))
    else:
        print(f"(no dry-run records at {path})")
    history = load_history(history_path)
    if history:
        print("\n### §Sim-lattice trajectory (BENCH_history.jsonl)\n")
        print(history_table(history))
    else:
        print(f"\n(no bench history at {history_path} — run "
              "`python -m benchmarks.run` to start the trajectory)")


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT_JSON)
    ap.add_argument("--history", default=HISTORY_PATH)
    ap.add_argument(
        "--gate", action="store_true",
        help="perf regression gate: exit 1 when the last BENCH_history.jsonl "
        "entry's steady_cells_per_sec regressed more than --max-regress vs "
        "the most recent prior entry on the same backend/mesh shape "
        "(passes trivially with no comparable prior)",
    )
    ap.add_argument(
        "--max-regress", type=float, default=0.2, metavar="FRAC",
        help="allowed fractional throughput drop for --gate (default 0.2)",
    )
    args = ap.parse_args()
    if args.gate:
        ok, msg = gate_regression(
            load_history(args.history), max_regress=args.max_regress
        )
        print(msg)
        sys.exit(0 if ok else 1)
    main(args.json, args.history)
