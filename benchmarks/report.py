"""Render §Dry-run / §Roofline markdown tables from dryrun_results.jsonl,
plus the sim-lattice perf trajectory from ``BENCH_history.jsonl`` (one
appended record per ``python -m benchmarks.run``, stamped with git SHA and
timestamp — see ``benchmarks.run.append_history``)."""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import DEFAULT_JSON, load_records, roofline_terms
from benchmarks.run import HISTORY_PATH


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB/dev | HLO FLOPs (global) "
        "| coll GiB/dev | params |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} ({r.get('reason','')[:40]}…) | – | – | – | – |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{r['cost']['flops_global']:.2e} | "
            f"{r['collective_bytes_per_device']/2**30:.1f} | "
            f"{r['params']/1e9:.1f}B |"
        )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | bound | "
        "MODEL/HLO FLOPs |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        t = roofline_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['useful_ratio']:.1%} |"
        )
    return "\n".join(lines)


def load_history(path: str = HISTORY_PATH) -> list[dict]:
    """The appended bench trajectory, oldest first ([] when never run).
    Malformed lines (a torn append) are skipped, not raised."""
    if not os.path.exists(path):
        return []
    entries = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return entries


def history_table(entries) -> str:
    """Markdown trajectory of the sim-lattice bench across commits."""
    lines = [
        "| when | sha | backend | mesh | hosts | cells | steady cells/s | "
        "compile_s | n_compiles | speedup |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in entries:
        lines.append(
            f"| {str(e.get('timestamp', '?'))[:19]} | {e.get('git_sha', '?')} | "
            f"{e.get('backend', '?')} | {e.get('mesh_devices', '?')} | "
            f"{e.get('n_hosts', '?')} | {e.get('cells', '?')} | "
            f"{e.get('steady_cells_per_sec', '?')} | "
            f"{e.get('compile_seconds', '?')} | {e.get('n_compiles', '?')} | "
            f"{e.get('speedup', '?')} |"
        )
    return "\n".join(lines)


def main(path=DEFAULT_JSON, history_path=HISTORY_PATH):
    if os.path.exists(path):
        recs = sorted(
            load_records(path), key=lambda r: (r["arch"], r["shape"], r["mesh"])
        )
        print("### §Dry-run records\n")
        print(dryrun_table(recs))
        print("\n### §Roofline (single-pod 16×16)\n")
        print(roofline_table(recs))
    else:
        print(f"(no dry-run records at {path})")
    history = load_history(history_path)
    if history:
        print("\n### §Sim-lattice trajectory (BENCH_history.jsonl)\n")
        print(history_table(history))
    else:
        print(f"\n(no bench history at {history_path} — run "
              "`python -m benchmarks.run` to start the trajectory)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT_JSON)
    ap.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args()
    main(args.json, args.history)
