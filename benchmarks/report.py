"""Render §Dry-run / §Roofline markdown tables from dryrun_results.jsonl."""
from __future__ import annotations

import argparse

from benchmarks.roofline import DEFAULT_JSON, load_records, roofline_terms


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB/dev | HLO FLOPs (global) "
        "| coll GiB/dev | params |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} ({r.get('reason','')[:40]}…) | – | – | – | – |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{r['cost']['flops_global']:.2e} | "
            f"{r['collective_bytes_per_device']/2**30:.1f} | "
            f"{r['params']/1e9:.1f}B |"
        )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | bound | "
        "MODEL/HLO FLOPs |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        t = roofline_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['useful_ratio']:.1%} |"
        )
    return "\n".join(lines)


def main(path=DEFAULT_JSON):
    recs = sorted(load_records(path), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("### §Dry-run records\n")
    print(dryrun_table(recs))
    print("\n### §Roofline (single-pod 16×16)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT_JSON)
    main(ap.parse_args().json)
