"""Fig. 5 — test accuracy vs noise power σ_z² ∈ {1e-12 … 1e-9}.

Paper claim validated: accuracy degrades with noise for every policy;
pofl's margin over the baselines grows in the noise-limited regime;
channel-aware degrades most.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import build_task, run_policies

NOISE_POWERS = (1e-12, 1e-11, 1e-10, 1e-9)


def main(full: bool = False):
    n_rounds = 100 if full else 30
    trials = 10 if full else 1
    task = build_task("mnist", n_train=6000 if full else 3000)
    policies = ("pofl", "importance", "channel", "deterministic")
    results = {}
    print("\n== Fig. 5 (accuracy vs σ_z², MNIST) ==")
    header = "  σ_z²      " + "".join(f"{p:>14s}" for p in policies)
    print(header)
    for np_ in NOISE_POWERS:
        r = run_policies(
            task, policies=policies, n_rounds=n_rounds, n_trials=trials,
            noise_power=np_, eval_every=max(n_rounds // 5, 1),
        )
        results[np_] = r
        row = f"  {np_:8.0e}  " + "".join(
            f"{r[p]['best_acc']:14.4f}" for p in policies
        )
        print(row)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
