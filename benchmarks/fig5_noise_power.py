"""Fig. 5 — test accuracy vs noise power σ_z² ∈ {1e-12 … 1e-9}.

Paper claim validated: accuracy degrades with noise for every policy;
pofl's margin over the baselines grows in the noise-limited regime;
channel-aware degrades most.

σ_z² is a vmapped lattice axis, so the whole figure — every (policy ×
noise × trial) cell — runs as one ``sim.lattice`` program per policy.
"""
from __future__ import annotations

import argparse

from benchmarks.common import build_task, policy_summary, sweep_lattice

NOISE_POWERS = (1e-12, 1e-11, 1e-10, 1e-9)


def main(full: bool = False):
    n_rounds = 100 if full else 30
    trials = 10 if full else 1
    task = build_task("mnist", n_train=6000 if full else 3000)
    policies = ("pofl", "importance", "channel", "deterministic")
    recs = sweep_lattice(
        task, policies=policies, noise_powers=NOISE_POWERS,
        n_rounds=n_rounds, n_trials=trials, eval_every=max(n_rounds // 5, 1),
    )
    results = {
        np_: {p: policy_summary(recs, p, np_, 0.1) for p in policies}
        for np_ in NOISE_POWERS
    }
    print("\n== Fig. 5 (accuracy vs σ_z², MNIST) ==")
    header = "  σ_z²      " + "".join(f"{p:>14s}" for p in policies)
    print(header)
    for np_ in NOISE_POWERS:
        row = f"  {np_:8.0e}  " + "".join(
            f"{results[np_][p]['best_acc']:14.4f}" for p in policies
        )
        print(row)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
