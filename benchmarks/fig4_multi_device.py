"""Fig. 4 — multi-device scheduling (|S^t| = 10), all 5 policies.

Paper claim validated: all policies improve over |S|=1; pofl matches the
noise-free bound; deterministic (biased, unweighted) converges slower.

Runs on the sim lattice via ``run_policies`` (trials vmapped per policy).
"""
from __future__ import annotations

import argparse

from benchmarks.common import build_task, print_table, run_policies


def main(full: bool = False):
    n_rounds = 100 if full else 40
    trials = 10 if full else 2
    results = {}
    for kind in ("mnist", "cifar") if full else ("mnist",):
        task = build_task(kind, n_train=6000 if full else 3000)
        r = run_policies(
            task, n_rounds=n_rounds, n_trials=trials, n_scheduled=10,
            eval_every=max(n_rounds // 10, 1),
        )
        print_table(f"Fig. 4 ({kind}, |S|=10)", r)
        results[kind] = r
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
