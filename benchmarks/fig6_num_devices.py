"""Fig. 6 — test accuracy vs number of scheduled devices |S^t|.

Paper claim validated: accuracy improves from |S|=1 to ~20 then degrades at
|S|=30 (distortion–variance tradeoff); pofl leads at every |S|, with the
largest margins at small |S|.

|S| changes the scheduling scan length (structural), so it loops in Python;
each |S| point runs its (policy × trial) grid on the sim lattice.
"""
from __future__ import annotations

import argparse

from benchmarks.common import build_task, run_policies

S_VALUES = (1, 5, 10, 20, 30)


def main(full: bool = False):
    n_rounds = 100 if full else 30
    trials = 10 if full else 1
    task = build_task("mnist", n_train=6000 if full else 3000)
    policies = ("pofl", "importance", "deterministic", "noisefree")
    results = {}
    print("\n== Fig. 6 (accuracy vs |S|, MNIST) ==")
    print("  |S|   " + "".join(f"{p:>14s}" for p in policies))
    svals = S_VALUES if full else (1, 10, 30)
    for s in svals:
        r = run_policies(
            task, policies=policies, n_rounds=n_rounds, n_trials=trials,
            n_scheduled=s, eval_every=max(n_rounds // 5, 1),
        )
        results[s] = r
        print(f"  {s:3d}   " + "".join(f"{r[p]['best_acc']:14.4f}" for p in policies))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
