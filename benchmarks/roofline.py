"""Roofline analysis (deliverable g): three-term model per (arch × shape),
derived from the dry-run's compiled artifacts, PLUS the sim's real hot path —
an ``aircomp`` row for the fused Eq. 5→8 aggregation kernel derived from the
lattice executable's own XLA ``cost_analysis``/``memory_analysis``.

    compute    = HLO_FLOPs / (chips · peak_FLOP/s)
    memory     = HLO_bytes / (chips · HBM_bw)
    collective = collective_bytes_per_device / link_bw

Hardware constants default to TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link — and are overridable per run via ``--peak-flops``,
``--hbm-bw``, ``--link-bw`` (values in FLOP/s and bytes/s) or the
``REPRO_ROOFLINE_PEAK_FLOPS`` / ``REPRO_ROOFLINE_HBM_BW`` /
``REPRO_ROOFLINE_LINK_BW`` environment variables (CLI wins over env wins
over the defaults).

Reads the JSONL emitted by ``python -m repro.launch.dryrun --json <path>``;
with no records available it prints instructions instead of fabricating
numbers. The aircomp row needs no dry run: it compiles a small sim lattice
in-process (fused backend, interpret mode on CPU) and reads the flops/bytes
XLA reports for that program — the fused kernel is VPU-bound, so its
roofline term is the HBM-bytes one (see kernels/aircomp/kernel.py).
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip (default; see hw_constants)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "dryrun_results.jsonl")

# MODEL_FLOPS token counts: 6·N·D training, 2·N·D inference fwd (per step).
# Unknown shapes fall back to model_flops=0 / useful_ratio=0 instead of
# KeyError — the compute/memory/collective terms don't need the token count.
_SHAPE_TOKENS = {
    "train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
    "decode_32k": 128, "long_500k": 1,
}


def hw_constants(
    peak_flops: float | None = None,
    hbm_bw: float | None = None,
    link_bw: float | None = None,
) -> tuple[float, float, float]:
    """Resolve (PEAK_FLOPS, HBM_BW, LINK_BW): explicit arg > REPRO_ROOFLINE_*
    env > the module-level TPU-v5e defaults."""

    def pick(arg, env_name, default):
        if arg is not None:
            return float(arg)
        env = os.environ.get(env_name)
        return float(env) if env else default

    return (
        pick(peak_flops, "REPRO_ROOFLINE_PEAK_FLOPS", PEAK_FLOPS),
        pick(hbm_bw, "REPRO_ROOFLINE_HBM_BW", HBM_BW),
        pick(link_bw, "REPRO_ROOFLINE_LINK_BW", LINK_BW),
    )


def load_records(path: str = DEFAULT_JSON) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the LAST record per (arch, shape, mesh) — reruns supersede
            recs[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(recs.values())


def roofline_terms(rec: dict, hw: tuple[float, float, float] | None = None) -> dict:
    peak_flops, hbm_bw, link_bw = hw or hw_constants()
    n = rec["n_devices"]
    flops_global = rec["cost"]["flops_global"]
    # whole-program bytes from the unrolled lowering (loop-faithful);
    # divided by chips for the per-device HBM term
    bytes_dev = rec["cost"]["bytes_accessed_global"] / n
    coll_dev = rec["collective_bytes_per_device"]
    compute_s = flops_global / (n * peak_flops)
    memory_s = bytes_dev / hbm_bw
    coll_s = coll_dev / link_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    shape_tokens = _SHAPE_TOKENS.get(rec["shape"])
    if shape_tokens is None:
        model_flops = 0.0  # unknown shape: no useful-FLOPs model, terms still valid
    else:
        mult = 6 if rec["shape"] == "train_4k" else 2
        model_flops = mult * rec["active_params"] * shape_tokens
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / flops_global if flops_global > 0 else 0.0,
    }


def aircomp_roofline(
    hw: tuple[float, float, float] | None = None,
    mesh=None,
) -> dict | None:
    """Roofline terms for the sim's REAL hot path: compile a small fused
    (``pallas_fused``, interpret on CPU) lattice sweep and read XLA's
    ``cost_analysis``/``memory_analysis`` off the engine's AOT executable
    (``sim.engine.lattice_cost_analysis``/``lattice_memory_analysis``).

    The fused aircomp kernel is one HBM pass over the (cells, N, D) gradient
    block with no MXU work, so its binding term is ``memory_s`` — the row
    this returns is expected (and asserted nowhere, printed honestly) to be
    HBM-bound. Returns None if the sweep fails (e.g. jax broken).
    """
    peak_flops, hbm_bw, _ = hw or hw_constants()
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
    from benchmarks.common import bench_task, run_policies
    from repro.sim.engine import _ENGINE_CACHE

    task = bench_task()
    run_policies(
        task, policies=("pofl",), n_rounds=5, n_trials=2,
        backend="pallas_fused", mesh=mesh,
    )
    eng = next(
        (e for e in reversed(_ENGINE_CACHE.values()) if e._lattice_executables),
        None,
    )
    if eng is None:
        return None
    cost = eng.lattice_cost_analysis()
    mem = eng.lattice_memory_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / peak_flops
    memory_s = bytes_acc / hbm_bw
    hbm_dev = 0
    if mem is not None:
        hbm_dev = (
            int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "output_size_in_bytes", 0))
            + int(getattr(mem, "temp_size_in_bytes", 0))
        )
    return {
        "flops": flops,
        "bytes_accessed": bytes_acc,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "dominant": "memory" if memory_s >= compute_s else "compute",
        "per_device_hbm_bytes": hbm_dev,
    }


def main(
    path: str = DEFAULT_JSON,
    peak_flops: float | None = None,
    hbm_bw: float | None = None,
    link_bw: float | None = None,
):
    hw = hw_constants(peak_flops, hbm_bw, link_bw)
    rows = []

    air = None
    try:
        air = aircomp_roofline(hw)
    except Exception as e:  # noqa: BLE001 - the dry-run rows must still print
        print(f"[roofline] aircomp lattice row unavailable: {type(e).__name__}: {e}")
    if air is not None:
        print("\n== Roofline: sim hot path (fused aircomp lattice) ==")
        print(
            f"{'kernel':>22s} {'compute_s':>12s} {'memory_s':>12s} "
            f"{'bound':>8s} {'MiB/dev':>8s}"
        )
        print(
            f"{'aircomp_fused':>22s} {air['compute_s']:12.3e} "
            f"{air['memory_s']:12.3e} {air['dominant']:>8s} "
            f"{air['per_device_hbm_bytes']/2**20:8.2f}"
        )
        rows.append(({"arch": "sim", "shape": "aircomp", "mesh": "-"}, air))

    recs = [r for r in load_records(path) if r.get("status") == "ok"]
    if not recs:
        print(
            "[roofline] no dry-run records found at", path,
            "\n  run: PYTHONPATH=src python -m repro.launch.dryrun"
            " --arch all --shape all --json", path,
        )
        return rows
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(f"\n== Roofline (from {len(recs)} dry-run records) ==")
    print(
        f"{'arch':>22s} {'shape':<12s} {'mesh':>8s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'bound':>10s} {'useful':>7s} {'GiB/dev':>8s}"
    )
    for r in recs:
        t = roofline_terms(r, hw)
        rows.append((r, t))
        print(
            f"{r['arch']:>22s} {r['shape']:<12s} {r['mesh']:>8s} "
            f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:10.4f} {t['dominant']:>10s} "
            f"{t['useful_ratio']:7.2%} "
            f"{r['memory']['peak_bytes']/2**30:8.2f}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT_JSON)
    ap.add_argument(
        "--peak-flops", type=float, default=None,
        help="peak FLOP/s per chip (default: TPU v5e 197e12; env "
        "REPRO_ROOFLINE_PEAK_FLOPS)",
    )
    ap.add_argument(
        "--hbm-bw", type=float, default=None,
        help="HBM bytes/s per chip (default 819e9; env REPRO_ROOFLINE_HBM_BW)",
    )
    ap.add_argument(
        "--link-bw", type=float, default=None,
        help="ICI bytes/s per link (default 50e9; env REPRO_ROOFLINE_LINK_BW)",
    )
    a = ap.parse_args()
    main(a.json, a.peak_flops, a.hbm_bw, a.link_bw)
