"""Roofline analysis (deliverable g): three-term model per (arch × shape),
derived from the dry-run's compiled artifacts.

    compute    = HLO_FLOPs / (chips · peak_FLOP/s)
    memory     = HLO_bytes / (chips · HBM_bw)
    collective = collective_bytes_per_device / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.

Reads the JSONL emitted by ``python -m repro.launch.dryrun --json <path>``;
with no records available it prints instructions instead of fabricating
numbers.
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "dryrun_results.jsonl")


def load_records(path: str = DEFAULT_JSON) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the LAST record per (arch, shape, mesh) — reruns supersede
            recs[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(recs.values())


def roofline_terms(rec: dict) -> dict:
    n = rec["n_devices"]
    flops_global = rec["cost"]["flops_global"]
    # whole-program bytes from the unrolled lowering (loop-faithful);
    # divided by chips for the per-device HBM term
    bytes_dev = rec["cost"]["bytes_accessed_global"] / n
    coll_dev = rec["collective_bytes_per_device"]
    compute_s = flops_global / (n * PEAK_FLOPS)
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    # MODEL_FLOPS: 6·N·D training, 2·N·D inference fwd (per step)
    shape_tokens = {
        "train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
        "decode_32k": 128, "long_500k": 1,
    }[rec["shape"]]
    mult = 6 if rec["shape"] == "train_4k" else 2
    model_flops = mult * rec["active_params"] * shape_tokens
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / flops_global if flops_global > 0 else 0.0,
    }


def main(path: str = DEFAULT_JSON):
    recs = [r for r in load_records(path) if r.get("status") == "ok"]
    if not recs:
        print(
            "[roofline] no dry-run records found at", path,
            "\n  run: PYTHONPATH=src python -m repro.launch.dryrun"
            " --arch all --shape all --json", path,
        )
        return []
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(f"\n== Roofline (from {len(recs)} dry-run records) ==")
    print(
        f"{'arch':>22s} {'shape':<12s} {'mesh':>8s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'bound':>10s} {'useful':>7s} {'GiB/dev':>8s}"
    )
    rows = []
    for r in recs:
        t = roofline_terms(r)
        rows.append((r, t))
        print(
            f"{r['arch']:>22s} {r['shape']:<12s} {r['mesh']:>8s} "
            f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:10.4f} {t['dominant']:>10s} "
            f"{t['useful_ratio']:7.2%} "
            f"{r['memory']['peak_bytes']/2**30:8.2f}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT_JSON)
    main(ap.parse_args().json)
