"""Fig. 3 — single-device scheduling (|S^t| = 1), all 5 policies,
MNIST-like (convex) and CIFAR-like (non-convex).

Paper claim validated: proposed (pofl) converges fastest and tracks the
noise-free upper bound; channel-aware fails to converge; deterministic lags.

Runs on the sim lattice via ``run_policies`` (trials vmapped per policy).
"""
from __future__ import annotations

import argparse

from benchmarks.common import build_task, print_table, run_policies


def main(full: bool = False):
    n_rounds = 100 if full else 40
    trials = 10 if full else 2
    results = {}
    for kind in ("mnist", "cifar") if full else ("mnist",):
        task = build_task(kind, n_train=6000 if full else 3000)
        r = run_policies(
            task, n_rounds=n_rounds, n_trials=trials, n_scheduled=1,
            eval_every=max(n_rounds // 10, 1),
        )
        print_table(f"Fig. 3 ({kind}, |S|=1)", r)
        results[kind] = r
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
