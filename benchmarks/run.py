"""Benchmark aggregator: one entry per paper table/figure + kernel
micro-benchmarks + the roofline table + the sim-lattice throughput bench.

Prints ``name,us_per_call,derived`` CSV lines (reduced settings — pass
--full to the individual modules for paper-scale runs), and writes
``BENCH_sim.json`` so future PRs have a perf trajectory. Every run is ALSO
appended — stamped with the git SHA and a UTC timestamp — to
``BENCH_history.jsonl`` next to it, so the trajectory survives the
overwrite (``python -m benchmarks.report`` renders it).

``BENCH_sim.json`` schema (one flat object):
  cells, n_rounds, n_devices       — sweep size (cells = algorithms ×
                                     policies × trials)
  backend                          — aggregation backend ("jnp"/"pallas_fused")
  task                             — model the lattice trained ("logreg" =
                                     the historical MNIST-shaped logistic
                                     regression; "cnn" = the CIFAR-shaped
                                     4-conv CNN, --task cnn). Part of the
                                     perf-gate key: CNN throughput is never
                                     compared against logreg entries (legacy
                                     history rows without the field gate
                                     only against each other)
  algorithms                       — local-update algorithms the lattice
                                     swept (``core.local_update.ALGORITHMS``
                                     names; ["fedavg"] = the historical
                                     single-algorithm bench); >1 name folds
                                     the traced algorithm axis into the same
                                     single compile (--algorithms a,b)
  local_steps                      — local SGD steps per device per round
                                     (1 = the historical single-gradient
                                     round; --local-steps K)
  mesh_devices                     — devices the cell axis was sharded over
                                     (1 = unsharded run; with --hosts N this
                                     is the GLOBAL process-spanning count)
  mesh_shape                       — "CxM" string of the (cells, model) mesh
                                     the lattice ran on ("1x1" = unsharded,
                                     "Nx1" = the 1-D cell sharding, "CxM"
                                     with M > 1 = the 2-D model-sharded
                                     mesh); the perf-gate key alongside
                                     backend
  per_device_hbm_bytes             — argument+output+temp bytes of the
                                     compiled lattice program PER DEVICE
                                     (XLA ``memory_analysis`` via
                                     ``sim.engine.lattice_memory_stats``;
                                     0 when unavailable, e.g. --hosts > 1).
                                     Shrinks as the model axis grows at
                                     fixed D — the 2-D mesh's headline
                                     number
  dim                              — flat model dimension D of the bench
                                     task's params (7850 for the default
                                     784-dim logreg; --dim overrides the
                                     feature dimension)
  n_hosts                          — jax.distributed process count the
                                     lattice ran across (1 = single-host)
  lattice_seconds / loop_seconds   — COLD lattice (trace + compile + run) vs
                                     cached-engine run_pofl loop (the loop
                                     baseline always runs single-host,
                                     unsharded)
  steady_seconds                   — identical repeat lattice call (cached
                                     engine + AOT executable: zero retraces,
                                     zero recompiles — pure run)
  compile_seconds                  — AOT ``lower().compile()`` wall time
                                     inside the cold call
                                     (``sim.engine.lattice_compile_stats``)
  n_compiles                       — distinct lattice programs compiled
                                     (1: the whole policy-fused sweep is one
                                     program; was one per policy before)
  speedup                          — loop_seconds / steady_seconds (honest
                                     steady-state lattice vs cached loop)
  cold_speedup                     — loop_seconds / lattice_seconds (the old
                                     compile-blended number, kept for the
                                     trajectory)
  cells_per_sec                    — cells / lattice_seconds (cold, blended —
                                     the historical trajectory number)
  steady_cells_per_sec             — cells / steady_seconds
  round_cells_per_sec              — cells × n_rounds / lattice_seconds
  per_device_cells_per_sec         — steady_cells_per_sec / mesh_devices (the
                                     sharding-efficiency trajectory number;
                                     steady-state since the one-compile PR)
  per_host_cells_per_sec           — steady_cells_per_sec / n_hosts (the
                                     multi-host scaling trajectory number)
  engine_cache_hits / _misses      — engine cache counters over the lattice
                                     cold+warm pair (misses == 1: one fused
                                     engine per lattice; with --hosts N they
                                     come from worker 0, where the lattice
                                     engines live)

Set ``REPRO_COMPILE_CACHE=<dir>`` to persist XLA compiles across runs
(``repro.sim.compile_cache``): a repeat cold run then reloads every lattice
program from disk instead of recompiling (compile_seconds collapses to the
deserialization cost).

``--backend {jnp,pallas_fused}`` selects the aggregation backend and
``--mesh N`` shards the lattice's cell axis over the first N local devices
(on CPU, export ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
first), both threaded through benchmarks/common.py. ``--mesh CxM`` (e.g.
``--mesh 4x2``) builds the 2-D ``("cells", "model")`` mesh instead — C
cell shards × M model shards per cell (``sim.lattice.make_cell_model_mesh``).
A ``--mesh`` exceeding the visible local device count is a HARD ERROR
(exit 2) — never a silent fall back to fewer devices. ``--dim D`` overrides
the bench task's feature dimension (D-scaling axis; 0 = the default 784)
and ``--sim-only`` runs just the sim-lattice bench (the perf-gate CI step).

``--hosts H`` (H > 1) measures the MULTI-HOST lattice instead: the sweep is
dispatched through ``repro.launch.distributed`` as H coordinated
``jax.distributed`` processes × (mesh/H) fake CPU devices each (no XLA_FLAGS
needed — the launcher sets each worker's pool), e.g.

    PYTHONPATH=src python -m benchmarks.run --hosts 2 --mesh 8

times the identical ``benchmarks.common.bench_sweep`` workload on a
2-process × 4-devices-per-process global mesh; ``--mesh`` must divide evenly
by ``--hosts`` (default: one device per host).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import time

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
HISTORY_PATH = os.path.join(_REPO_ROOT, "BENCH_history.jsonl")


def _git_sha() -> str:
    """The current commit SHA, or "unknown" outside a usable git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=_REPO_ROOT, capture_output=True, text=True, timeout=10,
            check=True,
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 - no git, not a repo, timeout: all "unknown"
        return "unknown"


def append_history(payload: dict, path: str = HISTORY_PATH) -> dict:
    """Append one timestamped+SHA-stamped bench record to the history JSONL.

    ``BENCH_sim.json`` is overwritten per run (latest-state contract);
    this file is the append-only trajectory behind it.
    """
    entry = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "git_sha": _git_sha(),
        **payload,
    }
    with open(path, "a", encoding="utf-8") as f:
        f.write(json.dumps(entry) + "\n")
    return entry


def _csv(name: str, seconds: float, derived: str):
    print(f"CSV,{name},{seconds*1e6:.0f},{derived}", flush=True)


def _run(name: str, fn, derive):
    t0 = time.time()
    try:
        out = fn()
        _csv(name, time.time() - t0, derive(out))
    except Exception as e:  # noqa: BLE001
        _csv(name, time.time() - t0, f"ERROR:{type(e).__name__}:{e}")


def _kernel_micro():
    """Interpret-mode kernel sanity micro-bench (CPU: correctness-path only)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.aircomp import aircomp_fused, aircomp_fused_ref
    from repro.kernels.attention import flash_attention, mha_ref
    from repro.kernels.ssd import ssd_naive, ssd_pallas

    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (30, 4096))
    coeff = jnp.ones((30,)) / 30
    z = jnp.zeros((4096,))
    got = aircomp_fused(g, coeff, jnp.float32(0.1), jnp.float32(1.0),
                        jnp.float32(2.0), z, interpret=True)
    want = aircomp_fused_ref(g, coeff, jnp.float32(0.1), jnp.float32(1.0),
                             jnp.float32(2.0), z)
    err_a = float(jnp.max(jnp.abs(got - want)))

    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    fa = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    err_f = float(jnp.max(jnp.abs(fa - mha_ref(q, k, v))))

    xdt = jax.random.normal(ks[3], (1, 64, 2, 16))
    la = -jnp.abs(jax.random.normal(ks[0], (1, 64, 2))) - 0.1
    B = jax.random.normal(ks[1], (1, 64, 8))
    C = jax.random.normal(ks[2], (1, 64, 8))
    sp = ssd_pallas(xdt, la, B, C, chunk=16, interpret=True)
    err_s = float(jnp.max(jnp.abs(sp - ssd_naive(xdt, la, B, C))))
    assert max(err_a, err_f, err_s) < 1e-3
    return f"max_abs_err={max(err_a, err_f, err_s):.2e}"


def _bench_sim(
    backend: str = "jnp",
    mesh_devices: int = 0,
    n_hosts: int = 1,
    model_shards: int = 1,
    dim: int = 0,
    algorithms: tuple = ("fedavg",),
    local_steps: int = 1,
    task_name: str = "logreg",
    checkpoint_every: int = 0,
):
    """Reduced fig4-style sweep (5 policies × 3 trials) through sim.lattice
    vs the cached-engine one-run_pofl-per-cell loop → BENCH_sim.json.

    ``task_name`` selects the model trained in every cell (``--task``):
    ``"logreg"`` is the historical 784-dim bench, ``"cnn"`` the CIFAR-shaped
    4-conv CNN — it lands in the payload (and so in the perf-gate key), so
    the two workloads' throughput trajectories never cross-compare.

    The lattice runs TWICE (cold, then an identical warm repeat), splitting
    ``lattice_seconds``/``compile_seconds`` from ``steady_seconds`` so
    compile cost stops blending into throughput; ``loop_seconds`` is the
    PR-2 optimized wrapper (engine cache + single-static-length active-mask
    scan), so ``speedup`` is the honest steady-lattice-vs-loop number and
    ``cold_speedup`` the old blended one. ``mesh_devices > 0`` shards the
    lattice's cell axis over that many local devices; ``model_shards > 1``
    additionally shards the model dimension (``--mesh CxM`` → a 2-D
    ``make_cell_model_mesh(C, M)`` mesh). ``dim > 0`` overrides the bench
    task's feature dimension. ``n_hosts > 1`` instead runs the lattice
    across that many coordinated ``jax.distributed`` processes via the
    ``repro.launch.distributed`` launcher (``mesh_devices`` then counts the
    GLOBAL devices; 1-D only). The loop baseline always runs single-host,
    unsharded.
    """
    from benchmarks.common import (
        BENCH_SWEEP_KW, POLICIES, bench_sweep, bench_task, run_policies_loop,
        timed,
    )
    from repro.sim import (
        engine_cache_stats,
        lattice_memory_stats,
        make_cell_mesh,
        make_cell_model_mesh,
        reset_engine_cache,
    )

    n_rounds = BENCH_SWEEP_KW["n_rounds"]
    # shared between the lattice sweep and loop baseline
    task_kind = {"logreg": "mnist", "cnn": "cifar"}[task_name]
    task = bench_task(dim=dim or None, kind=task_kind)
    from jax.flatten_util import ravel_pytree

    flat_dim = int(ravel_pytree(task.params0)[0].size)
    mem_stats = {"per_device_hbm_bytes": 0}
    if n_hosts > 1:
        from repro.launch.distributed import run_bench

        total = mesh_devices or n_hosts
        worker = run_bench(
            n_procs=n_hosts,
            devices_per_proc=total // n_hosts,
            backend=backend,
            n_rounds=n_rounds,
        )
        timings = {
            "cold_seconds": worker["lattice_seconds"],
            "steady_seconds": worker["steady_seconds"],
            "compile_seconds": worker["compile_seconds"],
            "n_compiles": worker["n_compiles"],
        }
        lattice_cache = {
            "hits": worker["engine_cache_hits"],
            "misses": worker["engine_cache_misses"],
        }
        cells = worker["cells"]
        n_mesh = worker["mesh_devices"]
        mesh_shape = f"{n_mesh}x1"
    else:
        if model_shards > 1:
            cells_ax = mesh_devices // model_shards
            mesh = make_cell_model_mesh(cells_ax, model_shards)
            mesh_shape = f"{cells_ax}x{model_shards}"
        elif mesh_devices:
            mesh = make_cell_mesh(mesh_devices)
            mesh_shape = f"{mesh_devices}x1"
        else:
            mesh = None
            mesh_shape = "1x1"
        n_mesh = 1 if mesh is None else mesh_devices
        _, timings, cells = bench_sweep(
            backend=backend, mesh=mesh, task=task,
            algorithms=algorithms, local_steps=local_steps,
        )
        lattice_cache = engine_cache_stats()
        # capture the per-device HBM footprint BEFORE the cache reset below
        # evicts the engines holding the compiled executables
        mem_stats = lattice_memory_stats()
    t_cold = timings["cold_seconds"]
    t_steady = timings["steady_seconds"]
    # --checkpoint-every: additionally time the SAME sweep through the
    # resilient chunked runner (repro.sim.resilience) — its own chunk
    # programs, so a cold and a warm pass — and record the checkpoint
    # overhead next to the primary timings. The primary (unchunked)
    # steady_cells_per_sec is untouched, so perf-gate keys stay comparable.
    ckpt_payload = {}
    if checkpoint_every:
        import tempfile

        from benchmarks.common import sweep_lattice

        ck_kw = dict(
            BENCH_SWEEP_KW, policies=POLICIES, backend=backend,
            algorithms=algorithms, local_steps=local_steps,
            checkpoint_every=checkpoint_every,
        )
        with tempfile.TemporaryDirectory() as td:
            # distinct dirs: the warm pass must re-run, not resume the cold
            _, t_ck_cold = timed(
                sweep_lattice, task,
                checkpoint_dir=os.path.join(td, "cold"), **ck_kw,
            )
            _, t_ck = timed(
                sweep_lattice, task,
                checkpoint_dir=os.path.join(td, "warm"), **ck_kw,
            )
        ckpt_payload = {
            "checkpoint_every": checkpoint_every,
            "checkpointed_seconds": round(t_ck, 3),
            "checkpointed_cold_seconds": round(t_ck_cold, 3),
            "checkpoint_overhead": round(t_ck / t_steady - 1.0, 3),
        }
    reset_engine_cache()
    # the loop baseline runs the IDENTICAL workload (same algorithms ×
    # policies × trials grid, same local_steps) so `speedup` stays honest
    kw = dict(
        BENCH_SWEEP_KW, policies=POLICIES, backend=backend,
        algorithms=algorithms, local_steps=local_steps,
    )
    _, t_loop = timed(run_policies_loop, task, **kw)

    payload = {
        "cells": cells,
        "n_rounds": n_rounds,
        "n_devices": 20,
        "backend": backend,
        "task": task_name,
        "algorithms": list(algorithms),
        "local_steps": local_steps,
        "mesh_devices": n_mesh,
        "mesh_shape": mesh_shape,
        "per_device_hbm_bytes": int(mem_stats["per_device_hbm_bytes"]),
        "dim": flat_dim,
        "n_hosts": n_hosts,
        "lattice_seconds": round(t_cold, 3),
        "steady_seconds": round(t_steady, 3),
        "compile_seconds": round(timings["compile_seconds"], 3),
        "n_compiles": timings["n_compiles"],
        "loop_seconds": round(t_loop, 3),
        "speedup": round(t_loop / t_steady, 2),
        "cold_speedup": round(t_loop / t_cold, 2),
        "cells_per_sec": round(cells / t_cold, 3),
        "steady_cells_per_sec": round(cells / t_steady, 3),
        "round_cells_per_sec": round(cells * n_rounds / t_cold, 1),
        "per_device_cells_per_sec": round(cells / t_steady / n_mesh, 3),
        "per_host_cells_per_sec": round(cells / t_steady / n_hosts, 3),
        "engine_cache_hits": lattice_cache["hits"],
        "engine_cache_misses": lattice_cache["misses"],
        **ckpt_payload,
    }
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_sim.json")
    with open(os.path.abspath(out_path), "w") as f:
        json.dump(payload, f, indent=2)
    append_history(payload)
    return payload


def main(argv: list[str] | None = None) -> None:
    from repro.core import BACKENDS
    from repro.sim import enable_compile_cache

    # REPRO_COMPILE_CACHE=<dir> persists every XLA compile below across runs
    # (no-op when unset); must precede the first compile to catch them all
    enable_compile_cache()

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend", default="jnp", choices=BACKENDS,
        help="aggregation backend for the sim-lattice bench",
    )
    parser.add_argument(
        "--mesh", type=str, default="0", metavar="N|CxM",
        help="shard the sim-lattice bench's cell axis over the first N local "
        "devices, or over a 2-D CxM (cells × model) mesh, e.g. --mesh 4x2 "
        "(0 = unsharded; on CPU set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=<total> first); "
        "with --hosts H this is the GLOBAL device count split H ways (1-D "
        "only)",
    )
    parser.add_argument(
        "--algorithms", type=str, default="fedavg", metavar="A[,B...]",
        help="comma-separated local-update algorithms for the sim-lattice "
        "bench (repro.core.local_update.ALGORITHMS names; >1 name sweeps "
        "the traced algorithm axis inside the same single compile); "
        "unknown or empty names are a hard error",
    )
    parser.add_argument(
        "--local-steps", type=int, default=1, metavar="K",
        help="local SGD steps per device per round for the sim-lattice "
        "bench (1 = the historical single-gradient round)",
    )
    parser.add_argument(
        "--task", default="logreg", choices=("logreg", "cnn"),
        help="model the sim-lattice bench trains: logreg (the historical "
        "784-dim task) or cnn (CIFAR-shaped 4-conv CNN, D≈2.6e5); recorded "
        "as `task` in BENCH_sim.json / BENCH_history.jsonl so the perf gate "
        "never compares the two workloads",
    )
    parser.add_argument(
        "--dim", type=int, default=0, metavar="D",
        help="override the bench task's feature dimension (0 = the default "
        "784-dim task; the flat model dimension lands in BENCH_sim.json "
        "as `dim`)",
    )
    parser.add_argument(
        "--checkpoint-every", type=int, default=0, metavar="K",
        help="additionally time the sim-lattice sweep through the resilient "
        "chunked runner (repro.sim.resilience), checkpointing the carry "
        "every K rounds; records checkpointed_seconds/checkpoint_overhead "
        "in BENCH_sim.json (0 = off; single-host, unsharded only)",
    )
    parser.add_argument(
        "--sim-only", action="store_true",
        help="run only the sim-lattice bench (the perf-gate CI step): "
        "writes BENCH_sim.json + BENCH_history.jsonl and skips the "
        "figure/kernel/roofline benches",
    )
    parser.add_argument(
        "--hosts", type=int, default=1, metavar="H",
        help="run the sim-lattice bench across H coordinated jax.distributed "
        "processes via repro.launch.distributed (1 = in-process)",
    )
    args = parser.parse_args(argv)

    # validate the topology UP FRONT: a --mesh that cannot be honored must
    # abort the whole run (exit 2), not degrade into a CSV ERROR line while
    # every other benchmark silently proceeds without BENCH_sim.json
    if args.hosts < 1:
        parser.error(f"--hosts must be >= 1 (got {args.hosts})")
    # validate the algorithm axis UP FRONT too: a malformed --algorithms is a
    # hard parser error (exit 2), never a mid-run CSV ERROR line
    from repro.core.local_update import ALGORITHMS

    algorithms = tuple(s.strip() for s in args.algorithms.split(","))
    if not algorithms or any(not a for a in algorithms):
        parser.error(f"--algorithms must be a,b,... names (got {args.algorithms!r})")
    for a in algorithms:
        if a not in ALGORITHMS:
            parser.error(
                f"--algorithms: unknown algorithm {a!r}; choose from {ALGORITHMS}"
            )
    if args.local_steps < 1:
        parser.error(f"--local-steps must be >= 1 (got {args.local_steps})")
    if args.hosts > 1 and (algorithms != ("fedavg",) or args.local_steps != 1):
        parser.error("--algorithms/--local-steps are single-host only")
    if args.checkpoint_every < 0:
        parser.error(f"--checkpoint-every must be >= 0 (got {args.checkpoint_every})")
    if args.checkpoint_every and args.hosts > 1:
        parser.error("--checkpoint-every is single-host only")
    try:
        if "x" in args.mesh:
            cells_s, model_s = args.mesh.split("x")
            mesh_total, model_shards = int(cells_s) * int(model_s), int(model_s)
            if int(cells_s) < 1 or model_shards < 1:
                raise ValueError(args.mesh)
        else:
            mesh_total, model_shards = int(args.mesh), 1
    except ValueError:
        parser.error(f"--mesh must be an integer N or CxM (got {args.mesh!r})")
    if mesh_total < 0:
        parser.error(f"--mesh must be >= 0 (got {args.mesh})")
    if args.dim < 0:
        parser.error(f"--dim must be >= 0 (got {args.dim})")
    if args.task == "cnn" and args.dim:
        parser.error("--dim only applies to the logreg task (cnn input shape is fixed)")
    if args.task == "cnn" and args.hosts > 1:
        parser.error("--task cnn is single-host only")
    if model_shards > 1 and args.hosts > 1:
        parser.error("--mesh CxM (model sharding) is single-host only")
    if args.checkpoint_every and mesh_total:
        parser.error(
            "--checkpoint-every is unsharded only (the chunked runner owns "
            "its own placement); drop --mesh"
        )
    if args.hosts == 1 and mesh_total:
        import jax

        n_local = len(jax.devices())
        if mesh_total > n_local:
            parser.error(
                f"--mesh {args.mesh} needs {mesh_total} devices but only "
                f"{n_local} local device(s) are visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={mesh_total}"
            )
    if args.hosts > 1 and (mesh_total or args.hosts) % args.hosts:
        parser.error(
            f"--mesh {args.mesh} must divide evenly across --hosts {args.hosts}"
        )

    from benchmarks import (
        fig3_single_device,
        fig4_multi_device,
        fig5_noise_power,
        fig6_num_devices,
        fig7_heterogeneity,
        roofline,
        table1_alpha,
    )

    if not args.sim_only:
        _run("kernels_microbench", _kernel_micro, lambda d: d)
    _run(
        "sim_lattice",
        lambda: _bench_sim(
            backend=args.backend, mesh_devices=mesh_total,
            n_hosts=args.hosts, model_shards=model_shards, dim=args.dim,
            algorithms=algorithms, local_steps=args.local_steps,
            task_name=args.task, checkpoint_every=args.checkpoint_every,
        ),
        lambda d: (
            "steady_cells/s=%.2f cold_cells/s=%.2f compile_s=%.1f "
            "n_compiles=%d speedup=%.1fx backend=%s task=%s mesh=%s "
            "hbm/dev=%d dim=%d hosts=%d" % (
                d["steady_cells_per_sec"], d["cells_per_sec"],
                d["compile_seconds"], d["n_compiles"], d["speedup"],
                d["backend"], d["task"], d["mesh_shape"],
                d["per_device_hbm_bytes"], d["dim"], d["n_hosts"],
            )
        ),
    )
    if args.sim_only:
        return
    _run(
        "fig3_single_device", fig3_single_device.main,
        lambda r: "pofl=%.3f noisefree=%.3f chan=%.3f" % (
            r["mnist"]["pofl"]["best_acc"],
            r["mnist"]["noisefree"]["best_acc"],
            r["mnist"]["channel"]["best_acc"],
        ),
    )
    _run(
        "fig4_multi_device", fig4_multi_device.main,
        lambda r: "pofl=%.3f det=%.3f" % (
            r["mnist"]["pofl"]["best_acc"],
            r["mnist"]["deterministic"]["best_acc"],
        ),
    )
    _run(
        "fig5_noise_power", fig5_noise_power.main,
        lambda r: "pofl@1e-9=%.3f chan@1e-9=%.3f" % (
            r[1e-9]["pofl"]["best_acc"], r[1e-9]["channel"]["best_acc"],
        ),
    )
    _run(
        "fig6_num_devices", fig6_num_devices.main,
        lambda r: "pofl@S1=%.3f pofl@S10=%.3f pofl@S30=%.3f" % (
            r[1]["pofl"]["best_acc"], r[10]["pofl"]["best_acc"],
            r[30]["pofl"]["best_acc"],
        ),
    )
    _run(
        "fig7_heterogeneity", fig7_heterogeneity.main,
        lambda r: "pofl@C1=%.3f pofl@C8=%.3f" % (
            r[1]["pofl"]["best_acc"], r[8]["pofl"]["best_acc"],
        ),
    )
    _run(
        "table1_alpha", table1_alpha.main,
        lambda r: "; ".join(
            f"s={k:.0e}:best_a={max(v, key=v.get)}" for k, v in r.items()
        ),
    )
    _run(
        "roofline", roofline.main,
        lambda rows: f"{len(rows)} (arch,shape,mesh) records",
    )


if __name__ == "__main__":
    main()
