"""Shared harness for the paper-figure benchmarks (Sec. V setup).

Builds the two evaluation tasks (MNIST-like logistic regression — convex;
CIFAR-like 4-conv CNN — non-convex) on seeded synthetic data with the
paper's non-IID shard partitioning, and runs the PO-FL simulator for a set
of scheduling policies.

Since the ``repro.sim`` subsystem landed, ``run_policies`` executes the whole
(policy × trial) grid through ``sim.lattice`` — one vmapped+scanned compile
per policy, metrics streamed out once — instead of looping ``run_pofl`` per
cell. ``run_policies_loop`` keeps the historical per-run loop as the perf
baseline for benchmarks/run.py's ``BENCH_sim.json``. ``sweep_lattice`` gives
figure modules direct access to the vmapped noise/alpha axes (fig5, table1).

``reduced=True`` (the default for ``python -m benchmarks.run``) shrinks
datasets/rounds/trials so the whole suite runs on CPU in minutes; pass
--full to individual figure modules for paper-scale runs.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.pofl import POFLConfig, run_pofl
from repro.data.partition import partition_noniid_shards
from repro.data.synthetic import make_classification_dataset
from repro.models import small
from repro.sim import LatticeRecords, LatticeSpec, run_lattice

POLICIES = ("pofl", "importance", "channel", "deterministic", "noisefree")


@dataclasses.dataclass
class Task:
    name: str
    loss_fn: object
    eval_fn: object
    params0: object
    data: object


def build_task(
    kind: str,
    n_devices: int = 30,
    classes_per_device: int = 2,
    n_train: int = 6000,
    n_test: int = 1000,
    seed: int = 0,
    dim: int | None = None,
) -> Task:
    """kind: 'mnist' (logreg) or 'cifar' (cnn).

    ``dim`` overrides the mnist task's flat feature dimension (the
    ``--dim`` benchmark axis: D scales the gradients/aggregation working
    set, which is what the 2-D model-sharded mesh shrinks per device).
    ``None`` keeps the historical 784 bit-identically; the CNN's D is
    fixed by its architecture, so ``dim`` with ``kind="cifar"`` raises.
    """
    if dim is not None and kind == "cifar":
        raise ValueError("dim override only supported for the mnist task")
    key = jax.random.PRNGKey(seed)
    k_train, k_test, k_init = jax.random.split(key, 3)
    ds = "mnist_like" if kind == "mnist" else "cifar_like"
    ds_kw = {"dim": dim} if (dim is not None and kind == "mnist") else {}
    x_tr, y_tr = make_classification_dataset(ds, n_train, k_train, **ds_kw)
    x_te, y_te = make_classification_dataset(ds, n_test, k_test, **ds_kw)
    data = partition_noniid_shards(
        x_tr, y_tr, n_devices, shards_per_device=classes_per_device, seed=seed
    )
    if kind == "mnist":
        params0 = small.init_logreg(k_init, dim=784 if dim is None else dim)
        loss_fn = small.logreg_loss
        eval_fn = small.make_eval_fn(small.logreg_logits, loss_fn, x_te, y_te)
    else:
        params0 = small.init_cnn(k_init)
        loss_fn = small.cnn_loss
        eval_fn = small.make_eval_fn(small.cnn_logits, loss_fn, x_te, y_te)
    return Task(kind, loss_fn, eval_fn, params0, data)


def _default_lr0(task: Task, lr0: float | None) -> float:
    return lr0 if lr0 is not None else (0.1 if task.name == "mnist" else 0.5)


def sweep_lattice(
    task: Task,
    policies=POLICIES,
    noise_powers=(1e-11,),
    alphas=(0.1,),
    n_rounds: int = 100,
    n_trials: int = 1,
    n_scheduled: int = 10,
    lr0: float | None = None,
    eval_every: int = 5,
    seed: int = 0,
    backend: str = "jnp",
    mesh=None,
    algorithms=("fedavg",),
    local_steps: int = 1,
    checkpoint_every: int | None = None,
    checkpoint_dir: str | None = None,
) -> LatticeRecords:
    """Run a full (algorithms × policies × noise_powers × alphas × trials)
    lattice.

    ``mesh`` (a ``jax.sharding.Mesh``, a device count, or None) shards the
    flattened cell axis — see ``repro.sim.lattice.run_lattice``. Results are
    identical to the unsharded run; only placement changes. ``algorithms``
    (``repro.core.local_update.ALGORITHMS`` names) and ``local_steps`` select
    the local-update axis; the defaults keep the historical single-gradient
    fedavg round bit-identically.

    ``checkpoint_every`` routes the sweep through the resilient chunked
    runner (``repro.sim.resilience.run_lattice_checkpointed``) instead,
    persisting the carry every that-many rounds under ``checkpoint_dir`` —
    the ``--checkpoint-every`` bench axis measuring checkpoint overhead.
    Single-host only (the chunked runner owns its own placement).
    """
    spec = LatticeSpec(
        policies=tuple(policies),
        noise_powers=tuple(noise_powers),
        alphas=tuple(alphas),
        seeds=tuple(seed + 1000 * t for t in range(n_trials)),
        n_rounds=n_rounds,
        eval_every=eval_every,
        algorithms=tuple(algorithms),
    )
    base_cfg = POFLConfig(
        n_devices=task.data.n_devices,
        n_scheduled=n_scheduled,
        lr0=_default_lr0(task, lr0),
        backend=backend,
        local_steps=local_steps,
    )
    if checkpoint_every is not None:
        if mesh is not None:
            raise ValueError("checkpoint_every and mesh are mutually exclusive")
        from repro.sim.resilience import run_lattice_checkpointed

        return run_lattice_checkpointed(
            task.loss_fn, task.data, task.params0, spec,
            base_cfg=base_cfg,
            eval_fn=task.eval_fn,
            channel_cfg=ChannelConfig(n_devices=task.data.n_devices),
            checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir,
        )
    return run_lattice(
        task.loss_fn, task.data, task.params0, spec,
        base_cfg=base_cfg,
        eval_fn=task.eval_fn,
        channel_cfg=ChannelConfig(n_devices=task.data.n_devices),
        mesh=mesh,
    )


def policy_summary(recs: LatticeRecords, policy: str, noise_power, alpha) -> dict:
    c = recs.cell(policy=policy, noise_power=noise_power, alpha=alpha)
    # (A, trials, evals) — fold the algorithm axis into the trial axis (A == 1
    # for the historical single-algorithm sweeps, a pure reshape)
    acc = c["acc"].reshape(-1, c["acc"].shape[-1])
    return {
        "acc": acc,
        "final_acc": float(np.mean(acc[:, -1])),
        "best_acc": float(np.mean(np.max(acc, axis=1))),
        "rounds": recs.eval_rounds.tolist(),
        "e_com": float(np.mean(c["e_com"])),
        "e_var": float(np.mean(c["e_var"])),
    }


def run_policies(
    task: Task,
    policies=POLICIES,
    n_rounds: int = 100,
    n_trials: int = 1,
    n_scheduled: int = 10,
    alpha: float = 0.1,
    noise_power: float = 1e-11,
    lr0: float | None = None,
    eval_every: int = 5,
    seed: int = 0,
    backend: str = "jnp",
    mesh=None,
    algorithms=("fedavg",),
    local_steps: int = 1,
) -> dict:
    """Returns {policy: {"acc": (algorithms·trials, evals), "rounds": [...],
    ...}} — same record layout as the historical run_pofl loop, computed on
    the sim lattice (all trials of a policy batched into one program, cells
    optionally sharded over ``mesh``; a multi-name ``algorithms`` folds the
    local-update axis into the same single compile)."""
    recs = sweep_lattice(
        task, policies=policies, noise_powers=(noise_power,), alphas=(alpha,),
        n_rounds=n_rounds, n_trials=n_trials, n_scheduled=n_scheduled,
        lr0=lr0, eval_every=eval_every, seed=seed, backend=backend, mesh=mesh,
        algorithms=algorithms, local_steps=local_steps,
    )
    return {
        p: policy_summary(recs, p, noise_power, alpha) for p in policies
    }


# the reduced fig4-style sweep benchmarks/run.py times for BENCH_sim.json —
# ONE definition shared by the in-process bench and the multi-host bench
# workers spawned by `repro.launch.distributed` (--hosts N), so the two
# timings measure the identical workload
BENCH_SWEEP_KW = dict(n_rounds=30, n_trials=3, n_scheduled=10, eval_every=10)


def bench_task(dim: int | None = None, kind: str = "mnist") -> Task:
    """The task the sim-lattice throughput bench runs on. ``dim`` overrides
    the flat feature dimension (the ``--dim`` D-scaling axis); ``None``
    keeps the historical 784-dim task bit-identically. ``kind`` selects the
    model (``benchmarks.run --task``): ``"mnist"`` is the historical logreg
    bench, ``"cifar"`` the 4-conv CNN (D ≈ 2.6×10⁵, smaller train set —
    throughput entries for the two tasks are never gate-compared)."""
    if kind == "cifar":
        return build_task("cifar", n_devices=20, n_train=1000, dim=dim)
    return build_task("mnist", n_devices=20, n_train=2000, dim=dim)


def bench_sweep(
    backend: str = "jnp", mesh=None, n_rounds: int | None = None, task=None,
    algorithms=("fedavg",), local_steps: int = 1,
):
    """Run the reduced benchmark sweep cold + warm → ``(results, timings, cells)``.

    ``timings`` separates compile cost from throughput honestly:

      cold_seconds     — first call (trace + XLA compile + run)
      steady_seconds   — identical repeat call (cached engine + executable:
                         zero retraces, zero recompiles — pure run)
      compile_seconds  — the engines' AOT ``lower().compile()`` wall time
                         (``repro.sim.engine.lattice_compile_stats``, scoped
                         by the engine-cache reset below)
      n_compiles       — distinct lattice programs compiled (1 for the
                         policy-fused lattice)

    ``mesh`` may be any ``run_policies`` mesh — including a process-spanning
    global mesh inside a ``jax.distributed`` worker (where every host runs
    this same call and gets the same timing shape).
    """
    from repro.sim import lattice_compile_stats, reset_engine_cache

    task = task or bench_task()
    kw = dict(
        BENCH_SWEEP_KW, policies=POLICIES, backend=backend,
        algorithms=tuple(algorithms), local_steps=local_steps,
    )
    if n_rounds is not None:
        kw["n_rounds"] = n_rounds
    reset_engine_cache()  # scope compile stats (and cold-ness) to this sweep
    out, cold = timed(run_policies, task, mesh=mesh, **kw)
    _, steady = timed(run_policies, task, mesh=mesh, **kw)
    timings = {
        "cold_seconds": cold,
        "steady_seconds": steady,
        **lattice_compile_stats(),
    }
    return out, timings, len(kw["algorithms"]) * len(POLICIES) * kw["n_trials"]


def run_policies_loop(
    task: Task,
    policies=POLICIES,
    n_rounds: int = 100,
    n_trials: int = 1,
    n_scheduled: int = 10,
    alpha: float = 0.1,
    noise_power: float = 1e-11,
    lr0: float | None = None,
    eval_every: int = 5,
    seed: int = 0,
    backend: str = "jnp",
    algorithms=("fedavg",),
    local_steps: int = 1,
) -> dict:
    """Historical harness: one ``run_pofl`` call per (algorithm × policy ×
    trial) — algorithms dispatch statically via ``cfg.local_algorithm``.

    Kept as the reference implementation and as the baseline the lattice's
    speedup is measured against (benchmarks/run.py → BENCH_sim.json). Since
    PR 2 this baseline itself benefits from the cross-call engine cache —
    trials of a policy differ only by seed, so only the first traces.
    """
    lr0 = _default_lr0(task, lr0)
    out = {}
    for policy in policies:
        accs, e_coms, e_vars = [], [], []
        rounds = None
        # algorithm-major, matching policy_summary's (A, trials) fold order
        for algorithm in algorithms:
            for trial in range(n_trials):
                cfg = POFLConfig(
                    n_devices=task.data.n_devices,
                    n_scheduled=n_scheduled,
                    alpha=alpha,
                    policy=policy,
                    noise_power=noise_power,
                    lr0=lr0,
                    seed=seed + 1000 * trial,
                    backend=backend,
                    local_algorithm=algorithm,
                    local_steps=local_steps,
                )
                _, hist = run_pofl(
                    task.loss_fn, task.params0, task.data, cfg, n_rounds,
                    eval_fn=task.eval_fn, eval_every=eval_every,
                    channel_cfg=ChannelConfig(
                        n_devices=task.data.n_devices, noise_power=noise_power
                    ),
                )
                accs.append(hist.test_acc)
                e_coms.append(np.mean(hist.e_com))
                e_vars.append(np.mean(hist.e_var))
                rounds = hist.test_round
        out[policy] = {
            "acc": np.asarray(accs),
            "final_acc": float(np.mean([a[-1] for a in accs])),
            "best_acc": float(np.mean([np.max(a) for a in accs])),
            "rounds": rounds,
            "e_com": float(np.mean(e_coms)),
            "e_var": float(np.mean(e_vars)),
        }
    return out


def print_table(title: str, results: dict, key: str = "best_acc"):
    print(f"\n== {title} ==")
    for policy, r in results.items():
        print(f"  {policy:>14s}: {key}={r[key]:.4f}  "
              f"e_com={r['e_com']:.3e}  e_var={r['e_var']:.3e}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
