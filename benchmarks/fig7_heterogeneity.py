"""Fig. 7 — test accuracy vs data heterogeneity C (classes per device).

Paper claim validated: smaller C (more heterogeneity) slows training for
every policy; pofl's advantage is largest at small C; near-IID (C=8,10)
pofl approaches the noise-free bound.

C changes the data partition (structural), so it loops in Python; each C
point runs its (policy × trial) grid on the sim lattice.
"""
from __future__ import annotations

import argparse

from benchmarks.common import build_task, run_policies

C_VALUES = (1, 2, 4, 8, 10)


def main(full: bool = False):
    n_rounds = 100 if full else 30
    trials = 10 if full else 1
    policies = ("pofl", "importance", "deterministic", "noisefree")
    results = {}
    print("\n== Fig. 7 (accuracy vs classes/device C, MNIST) ==")
    print("   C    " + "".join(f"{p:>14s}" for p in policies))
    cvals = C_VALUES if full else (1, 2, 8)
    for c in cvals:
        task = build_task(
            "mnist", classes_per_device=c, n_train=6000 if full else 3000
        )
        r = run_policies(
            task, policies=policies, n_rounds=n_rounds, n_trials=trials,
            eval_every=max(n_rounds // 5, 1),
        )
        results[c] = r
        print(f"  {c:3d}   " + "".join(f"{r[p]['best_acc']:14.4f}" for p in policies))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(ap.parse_args().full)
